"""Test-suite bootstrap.

Two jobs:

* put ``src/`` on ``sys.path`` so the suite runs without an editable
  install (CI does ``pip install -e .``; local quickstart may not);
* if the real ``hypothesis`` package is unavailable (the CI image has it,
  minimal containers may not), install a tiny API-compatible fallback that
  runs each property test on a deterministic pseudo-random sample.  The
  fallback covers exactly the subset the suite uses: ``given``,
  ``settings(max_examples=, deadline=)`` and the ``integers`` / ``floats``
  / ``sampled_from`` / ``booleans`` strategies.
"""
from __future__ import annotations

import functools
import inspect
import os
import random
import sys
import types

_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _install_hypothesis_stub() -> None:
    class _Strategy:
        def __init__(self, sample, boundary=()):
            self._sample = sample
            self._boundary = tuple(boundary)

        def example(self, rng: random.Random, i: int):
            # hit the boundary values first, then sample randomly
            if i < len(self._boundary):
                return self._boundary[i]
            return self._sample(rng)

    def integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(lo, hi), (lo, hi))

    def floats(lo: float, hi: float, **_kw) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(lo, hi), (lo, hi))

    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq), seq[:1])

    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5, (False, True))

    def settings(max_examples: int = 100, deadline=None, **_kw):
        def deco(f):
            f._stub_max_examples = max_examples
            return f
        return deco

    def given(*strats, **kwstrats):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples",
                            getattr(f, "_stub_max_examples", 25))
                rng = random.Random(0xC0FFEE)
                for i in range(n):
                    vals = [s.example(rng, i) for s in strats]
                    kws = {k: s.example(rng, i)
                           for k, s in kwstrats.items()}
                    f(*args, *vals, **kwargs, **kws)

            # hide the strategy-bound parameters from pytest's fixture
            # resolution: the wrapper supplies them itself
            del wrapper.__wrapped__
            params = list(
                inspect.signature(f).parameters.values())
            if strats:
                params = params[: -len(strats) or None]
            params = [p for p in params if p.name not in kwstrats]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__stub__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_stub()
