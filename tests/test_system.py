"""End-to-end system tests: training converges on the synthetic corpus,
resumes exactly after a simulated failure, and serving with continuous
batching produces tokens; the dry-run path compiles on a small mesh."""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import main
    out = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "25",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", str(tmp_path / "ck")])
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_restart_resumes(tmp_path):
    """Simulated failure: run 10 steps, 'crash', restart to 16 — the
    resumed run continues from the checkpoint, not from scratch."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    first = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "10",
                  "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                  "--ckpt-every", "5"])
    second = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "16",
                   "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                   "--ckpt-every", "5"])
    # resumed run executed only steps 10..16
    assert len(second["losses"]) == 6
    # and continued improving from where the first left off
    assert second["losses"][-1] < first["losses"][0]


def test_serve_continuous_batching():
    from repro.launch.serve import main
    out = main(["--arch", "qwen2-0.5b", "--reduced", "--requests", "5",
                "--batch", "2", "--prompt-len", "8", "--gen", "6"])
    lens = [len(v) for v in out["outputs"].values()]
    assert sorted(lens, reverse=True)[:4] == [6, 6, 6, 6]
    assert sum(lens) >= 5 * 6 - 6  # last slot may hit the cache limit
    # flight-recorder metrics: per-request latency summary is populated
    lat = out["latency_s"]
    assert lat["count"] >= 4
    assert 0 < lat["mean_s"] <= lat["max_s"] <= lat["p99_s"] * 2 + 1e-9
    assert lat["p50_s"] > 0


def test_dryrun_cell_compiles_small_mesh():
    """Run the dry-run code path in a subprocess with 8 fake devices and a
    reduced config: proves lower+compile+analysis works end-to-end without
    the 512-device production mesh (which the full sweep covers)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.launch.steps import ShapeSpec, input_specs, make_train_step
from repro.launch.hloanalysis import analyze
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("qwen3-4b", reduced=True)
shape = ShapeSpec("tiny_train", "train", 64, 8)
with mesh:
    sp = input_specs(cfg, shape, mesh)
    fn = make_train_step(cfg)
    compiled = jax.jit(fn).lower(sp["params"], sp["opt_state"],
                                 sp["batch"]).compile()
costs = analyze(compiled.as_text())
assert costs.dot_flops > 0
assert compiled.memory_analysis() is not None
print("OK", costs.dot_flops)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=REPO, env=env, timeout=480)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_dryrun_sweep_results_have_no_errors():
    """If the full 80-cell sweep has been run, every cell must be ok or an
    explicitly documented skip."""
    res_dir = REPO / "results" / "dryrun"
    if not res_dir.exists():
        pytest.skip("full sweep not run in this environment")
    recs = [json.loads(p.read_text()) for p in res_dir.glob("*.json")]
    assert len(recs) >= 80
    bad = [(r["arch"], r["shape"], r["mesh"]) for r in recs
           if r["status"] not in ("ok", "skipped")]
    assert not bad, f"dry-run failures: {bad}"
    skips = [r for r in recs if r["status"] == "skipped"]
    assert all("full-attention" in r["reason"] for r in skips)


# ----------------------------------------------------------------------
# ServeLoop lifecycle: the continuous-batching loop as an object
# ----------------------------------------------------------------------
def _serve_loop(requests, batch=2, gen=6, seed=0):
    import jax
    from repro.configs import get_config
    from repro.launch.serve import ServeLoop
    from repro.models import get_api
    cfg = get_config("qwen2-0.5b", reduced=True)
    api = get_api(cfg)
    params, _ = api.init(cfg, jax.random.PRNGKey(seed))
    loop = ServeLoop(api, cfg, params, batch=batch, prompt_len=8,
                     gen=gen, seed=seed)
    rng = np.random.default_rng(seed)
    for r in range(requests):
        loop.submit(r, rng.integers(1, cfg.vocab_size, size=8))
    return loop


def test_serve_loop_metrics_under_concurrent_clients():
    """Queue-depth gauge and request-latency histogram track the five
    clients through admission, refill, and completion."""
    from repro.obs import metrics
    metrics.reset()
    loop = _serve_loop(5, batch=2)
    depth = metrics.gauge("serve.queue_depth")
    assert depth.value == 5          # all five queued before the wave
    loop.start()
    assert depth.value == 3 and loop.active == 2
    loop.drain()
    assert depth.value == 0 and loop.pending == 0
    assert depth.max == 5
    snap = metrics.snapshot()
    lat = snap["serve.request_latency_s"]
    assert lat["count"] == loop.served >= 4
    assert loop.latencies and min(loop.latencies) > 0
    # later submissions waited in the queue at least as long
    assert max(loop.latencies) >= min(loop.latencies)
    assert snap["serve.tokens"]["value"] == sum(
        len(v) for v in loop.outputs.values())


def test_serve_loop_cancellation_mid_batch():
    """A queued request cancels instantly; a decoding request frees its
    slot at the next step (refilled from the queue, no latency row)."""
    from repro.obs import metrics
    metrics.reset()
    loop = _serve_loop(4, batch=2, gen=6)
    assert loop.cancel(3)            # still queued: dropped outright
    loop.start()
    assert loop.step()
    assert loop.cancel(0)            # mid-batch: slot frees next step
    assert not loop.cancel(99)       # unknown
    loop.drain()
    assert len(loop.outputs[0]) < 6      # partial output kept
    assert len(loop.outputs[3]) == 0     # never admitted
    assert len(loop.outputs[1]) == len(loop.outputs[2]) == 6
    assert loop.served == 2
    snap = metrics.snapshot()
    assert snap["serve.request_latency_s"]["count"] == 2
    assert not loop.cancel(1)        # already finished


def test_serve_loop_shutdown_drains_in_flight():
    """shutdown(drain=True) finishes the admitted slots and refuses new
    work; queued-but-unstarted requests stay unserved."""
    loop = _serve_loop(6, batch=2, gen=6)
    loop.start()
    assert loop.step()
    loop.shutdown(drain=True)
    assert loop.served == 2 and loop.active == 0
    assert len(loop.outputs[0]) == len(loop.outputs[1]) == 6
    assert loop.pending == 4         # never admitted after close
    assert all(len(loop.outputs[r]) == 0 for r in range(2, 6))
    with pytest.raises(RuntimeError):
        loop.submit(7, np.ones(8, np.int32))


def test_serve_loop_shutdown_abandons_without_drain():
    loop = _serve_loop(3, batch=2, gen=6)
    loop.start()
    assert loop.step()
    loop.shutdown(drain=False)
    assert loop.active == 0 and loop.served == 0
    assert not loop.step()           # idle and closed
    assert all(len(v) <= 1 for v in loop.outputs.values())
