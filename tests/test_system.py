"""End-to-end system tests: training converges on the synthetic corpus,
resumes exactly after a simulated failure, and serving with continuous
batching produces tokens; the dry-run path compiles on a small mesh."""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import main
    out = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "25",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", str(tmp_path / "ck")])
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_train_restart_resumes(tmp_path):
    """Simulated failure: run 10 steps, 'crash', restart to 16 — the
    resumed run continues from the checkpoint, not from scratch."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    first = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "10",
                  "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                  "--ckpt-every", "5"])
    second = main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "16",
                   "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                   "--ckpt-every", "5"])
    # resumed run executed only steps 10..16
    assert len(second["losses"]) == 6
    # and continued improving from where the first left off
    assert second["losses"][-1] < first["losses"][0]


def test_serve_continuous_batching():
    from repro.launch.serve import main
    out = main(["--arch", "qwen2-0.5b", "--reduced", "--requests", "5",
                "--batch", "2", "--prompt-len", "8", "--gen", "6"])
    lens = [len(v) for v in out["outputs"].values()]
    assert sorted(lens, reverse=True)[:4] == [6, 6, 6, 6]
    assert sum(lens) >= 5 * 6 - 6  # last slot may hit the cache limit
    # flight-recorder metrics: per-request latency summary is populated
    lat = out["latency_s"]
    assert lat["count"] >= 4
    assert 0 < lat["mean_s"] <= lat["max_s"] <= lat["p99_s"] * 2 + 1e-9
    assert lat["p50_s"] > 0


def test_dryrun_cell_compiles_small_mesh():
    """Run the dry-run code path in a subprocess with 8 fake devices and a
    reduced config: proves lower+compile+analysis works end-to-end without
    the 512-device production mesh (which the full sweep covers)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_config
from repro.launch.steps import ShapeSpec, input_specs, make_train_step
from repro.launch.hloanalysis import analyze
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("qwen3-4b", reduced=True)
shape = ShapeSpec("tiny_train", "train", 64, 8)
with mesh:
    sp = input_specs(cfg, shape, mesh)
    fn = make_train_step(cfg)
    compiled = jax.jit(fn).lower(sp["params"], sp["opt_state"],
                                 sp["batch"]).compile()
costs = analyze(compiled.as_text())
assert costs.dot_flops > 0
assert compiled.memory_analysis() is not None
print("OK", costs.dot_flops)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=REPO, env=env, timeout=480)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_dryrun_sweep_results_have_no_errors():
    """If the full 80-cell sweep has been run, every cell must be ok or an
    explicitly documented skip."""
    res_dir = REPO / "results" / "dryrun"
    if not res_dir.exists():
        pytest.skip("full sweep not run in this environment")
    recs = [json.loads(p.read_text()) for p in res_dir.glob("*.json")]
    assert len(recs) >= 80
    bad = [(r["arch"], r["shape"], r["mesh"]) for r in recs
           if r["status"] not in ("ok", "skipped")]
    assert not bad, f"dry-run failures: {bad}"
    skips = [r for r in recs if r["status"] == "skipped"]
    assert all("full-attention" in r["reason"] for r in skips)
