"""Device-resident fused search (``repro.search.fused``).

The contracts under test: the traced decode matches the host
``decode_bucketed`` bit-for-bit; a fused run is bit-reproducible from
its key, runs with ZERO scalar evaluations and one scan compile per
(length, pop, genome) shape, and its winner is re-validated by the
scalar oracle; ineligible runs fall back to the host loop with a
warning; the end-to-end ``value_and_grad`` path through the bucketed
model matches central finite differences of the scalar oracle on every
ArchParams column (and of the traced surrogate loss itself); fused
generation records carry honest ``wall_time_s=None`` timing; and the
fused island mode routes chunk dispatches through the shared service.
"""
import dataclasses

import jax.numpy as jnp
import jax.random as jrandom
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import Sparseloop, compile_stats, matmul
from repro.core.arch import (ArchParams, COMPUTE_FIELDS, STORAGE_FIELDS,
                             pack_arch_params)
from repro.core.mapper import MapspaceConstraints
from repro.core.presets import (coordinate_list_design, scnn_like,
                                three_level_arch, two_level_arch)
from repro.search import (CoSearchEncoding, DesignSpace, GenerationRecord,
                          MapspaceEncoding, SearchLog, fused_supported,
                          get_fused_program, make_strategy, run_search)

WL = matmul(32, 32, 32, densities={"A": ("uniform", 0.3),
                                   "B": ("uniform", 0.3)})
DESIGN = coordinate_list_design(two_level_arch(buffer_kwords=8))
CONS = MapspaceConstraints(budget=96, seed=0, spatial={1: {"n": 4}})


def _space():
    return DesignSpace(
        capacity_steps={"Buffer": (2 * 1024, 8 * 1024, 64 * 1024)},
        extra_steps={("Buffer", "read_energy_pj"): (3.0, 6.0, 12.0)},
        compute_steps={"mac_energy_pj": (0.5, 1.0, 2.0)})


# ----------------------------------------------------------------------
# eligibility + traced decode parity
# ----------------------------------------------------------------------
def test_fused_supported():
    enc = MapspaceEncoding(WL, 2, CONS)
    assert fused_supported(enc)
    assert fused_supported(
        CoSearchEncoding(WL, 2, CONS, _space(), DESIGN))
    # a knob on a STATIC field (word_bits reshapes the trace) is not
    # traceable -> host loop only
    static = DesignSpace(extra_steps={("Buffer", "word_bits"):
                                      (8.0, 16.0)})
    assert not fused_supported(
        CoSearchEncoding(WL, 2, CONS, static, DESIGN))


@pytest.mark.parametrize("cons", [
    CONS,
    MapspaceConstraints(budget=96, seed=0),                 # no spatial
    MapspaceConstraints(budget=96, seed=0, spatial={1: {"n": 4}},
                        permutations={0: ("n", "k", "m"),
                                      1: ("m", "n")}),      # pinned order
])
def test_traced_decode_matches_host_decode(cons):
    enc = MapspaceEncoding(WL, 2, cons)
    pop = enc.random_population(jrandom.PRNGKey(0), 16)
    bucket, bounds, ids = enc.decode_bucketed(pop)
    bm = Sparseloop(DESIGN).bucketed_model(WL, bucket)
    fp = get_fused_program(bm, enc, make_strategy("es"))
    with enable_x64():
        for g, b_ref, i_ref in zip(pop, bounds, ids):
            b, i = fp._decode_map(jnp.asarray(g, jnp.int32))
            np.testing.assert_array_equal(np.asarray(b), b_ref)
            np.testing.assert_array_equal(np.asarray(i), i_ref)


# ----------------------------------------------------------------------
# fused runs: determinism, compile accounting, oracle-validated winner
# ----------------------------------------------------------------------
def test_fused_run_deterministic_and_validated():
    with compile_stats.track() as st:
        runs = [run_search(DESIGN, WL, CONS, strategy="es", key=5,
                           mesh=None, fused=True) for _ in range(2)]
    a, b = runs
    assert a.log.to_json(timing=False) == b.log.to_json(timing=False)
    # zero scalar evals, exactly one scan compile for both runs (the
    # FusedProgram is cached and both runs share one chunk shape)
    assert st.scalar_evals == 0
    assert st.compiles_by_kind.get("fused", 0) == 1
    # honest timing: generations inside the scan have no wall time,
    # chunk dispatches do
    assert all(r.wall_time_s is None for r in a.log.records)
    assert a.log.timing["fused"] is True
    assert sum(c["generations"] for c in a.log.timing["chunks"]) == \
        len(a.log.records)
    # the winner carries the host contract: scalar-oracle validated
    assert a.best is not None and a.best.result.valid
    oracle = Sparseloop(DESIGN).evaluate(WL, a.best_nest)
    assert a.best.edp == pytest.approx(oracle.edp, rel=1e-9)
    assert a.log.evaluations == len(a.log.records) * 32


def test_fused_chunking_invariant():
    """Chunk boundaries are a dispatch artifact: the trajectory is
    identical whatever fused_chunk says."""
    from repro.search import SearchConfig
    logs = []
    for chunk in (2, 100):
        cfg = SearchConfig(fused_chunk=chunk)
        logs.append(run_search(DESIGN, WL, CONS, strategy="es", key=5,
                               mesh=None, fused=True, config=cfg).log)
    assert logs[0].to_json(timing=False) == logs[1].to_json(timing=False)


def test_fused_fallback_warns_and_matches_host():
    """A non-ES strategy is not fused-eligible: explicit fused=True
    warns and the run is byte-identical to the plain host run."""
    with pytest.warns(UserWarning, match="not fused-eligible"):
        fell_back = run_search(DESIGN, WL, CONS, strategy="hillclimb",
                               key=3, mesh=None, fused=True)
    host = run_search(DESIGN, WL, CONS, strategy="hillclimb", key=3,
                      mesh=None)
    assert fell_back.log.to_json(timing=False) == \
        host.log.to_json(timing=False)
    assert "fused" not in fell_back.log.timing


def test_fused_cosearch_with_hybrid_sgd():
    """Co-search (storage + compute knobs) through the fused path, with
    the Lamarckian SGD nudge on: deterministic, oracle-validated under
    the winner's own design, and no worse than the pure-ES fused run at
    equal budget."""
    space = _space()
    kw = dict(strategy="es", key=9, mesh=None, design_space=space,
              fused=True)
    runs = [run_search(DESIGN, WL, CONS, sgd_lr=0.5, **kw)
            for _ in range(2)]
    a, b = runs
    assert a.log.to_json(timing=False) == b.log.to_json(timing=False)
    assert a.best_design is not None
    oracle = Sparseloop(a.best_design).evaluate(WL, a.best_nest)
    assert a.best.result.valid
    assert a.best.edp == pytest.approx(oracle.edp, rel=1e-9)
    pure = run_search(DESIGN, WL, CONS, sgd_lr=0.0, **kw)
    assert a.best.edp <= pure.best.edp * (1 + 1e-9)


# ----------------------------------------------------------------------
# gradient parity: value_and_grad vs central finite differences
# ----------------------------------------------------------------------
def _oracle_edp(arch, nest):
    return Sparseloop(dataclasses.replace(DESIGN, arch=arch)).evaluate(
        WL, nest, check_capacity=False).edp


def _perturb_storage(arch, s, j, v):
    name = arch.level(s).name
    field = STORAGE_FIELDS[j]
    levels = tuple(dataclasses.replace(lv, **{field: v})
                   if lv.name == name else lv for lv in arch.levels)
    return dataclasses.replace(arch, levels=levels)


def _perturb_compute(arch, j, v):
    field = COMPUTE_FIELDS[j]
    v = int(round(v)) if field == "instances" else v
    return dataclasses.replace(
        arch, compute=dataclasses.replace(arch.compute, **{field: v}))


def test_arch_grad_matches_scalar_oracle_fd():
    """d(EDP)/d(column) from one value_and_grad pass matches a central
    finite difference of the SCALAR oracle <= 1e-3 relative, for every
    finite ArchParams storage and compute column (plateaued columns —
    capacity, bandwidth — agree on zero)."""
    enc = MapspaceEncoding(WL, 2, CONS)
    pop = enc.random_population(jrandom.PRNGKey(0), 8)
    bucket, bounds, ids = enc.decode_bucketed(pop)
    bm = Sparseloop(DESIGN).bucketed_model(WL, bucket,
                                           check_capacity=True)
    out = bm.evaluate_with_arch_grad(bounds, ids, metric="edp")
    assert out["grad_storage"].shape == (8, 2, len(STORAGE_FIELDS))
    assert out["grad_compute"].shape == (8, len(COMPUTE_FIELDS))
    c = int(np.flatnonzero(out["valid"])[0])
    nest = enc.nest_of(pop[c])
    arch = DESIGN.arch
    ap = pack_arch_params(arch)
    scale = abs(float(out["edp"][c]))

    def check(g, fd):
        if abs(fd) < 1e-12 * scale:
            assert abs(g) < 1e-9 * scale
        else:
            assert g == pytest.approx(fd, rel=1e-3)

    for s in range(2):
        for j in range(len(STORAGE_FIELDS)):
            x = float(ap.storage[s, j])
            if not np.isfinite(x):
                continue
            h = 1e-4 * max(abs(x), 1.0)
            fd = (_oracle_edp(_perturb_storage(arch, s, j, x + h), nest)
                  - _oracle_edp(_perturb_storage(arch, s, j, x - h),
                                nest)) / (2 * h)
            check(float(out["grad_storage"][c, s, j]), fd)
    for j, field in enumerate(COMPUTE_FIELDS):
        x = float(ap.compute[j])
        h = 1.0 if field == "instances" else 1e-4 * max(abs(x), 1.0)
        fd = (_oracle_edp(_perturb_compute(arch, j, x + h), nest)
              - _oracle_edp(_perturb_compute(arch, j, x - h),
                            nest)) / (2 * h)
        check(float(out["grad_compute"][c, j]), fd)


def test_surrogate_grad_matches_traced_fd():
    """The smooth capacity-surrogate loss is consistent with its own
    gradients: FD of the traced loss w.r.t. perturbed ArchParams rows
    matches grad_storage <= 1e-3 relative — including the capacity
    column, which the surrogate (unlike the hard mask) makes
    differentiable."""
    enc = MapspaceEncoding(WL, 2, CONS)
    pop = enc.random_population(jrandom.PRNGKey(1), 4)
    bucket, bounds, ids = enc.decode_bucketed(pop)
    bm = Sparseloop(DESIGN).bucketed_model(WL, bucket,
                                           check_capacity=True)
    ap = pack_arch_params(DESIGN.arch)
    out = bm.evaluate_with_arch_grad(bounds, ids, metric="edp",
                                     surrogate=True, tau=0.05)
    assert np.isfinite(out["loss"]).all()
    c = int(np.flatnonzero(out["valid"])[0])

    def loss_at(storage):
        pert = ArchParams(storage=storage, compute=ap.compute,
                          structure=ap.structure)
        return float(bm.evaluate_with_arch_grad(
            bounds, ids, arch_params=pert, metric="edp",
            surrogate=True, tau=0.05)["loss"][c])

    for (s, j) in [(0, STORAGE_FIELDS.index("capacity_words")),
                   (0, STORAGE_FIELDS.index("read_energy_pj")),
                   (1, STORAGE_FIELDS.index("metadata_read_energy_pj"))]:
        x = float(ap.storage[s, j])
        h = 1e-5 * max(abs(x), 1.0)
        up = np.array(ap.storage)
        up[s, j] = x + h
        dn = np.array(ap.storage)
        dn[s, j] = x - h
        fd = (loss_at(up) - loss_at(dn)) / (2 * h)
        g = float(out["grad_storage"][c, s, j])
        if abs(fd) < 1e-12:
            assert abs(g) < 1e-9
        else:
            assert g == pytest.approx(fd, rel=1e-3)


# ----------------------------------------------------------------------
# SearchLog timing honesty for fused records
# ----------------------------------------------------------------------
def test_log_none_wall_time_roundtrip():
    log = SearchLog(strategy="es", metric="edp")
    log.append(GenerationRecord(0, 32, 30, 1.0, 1.0, 1.0, 1.0,
                                wall_time_s=None))
    log.append(GenerationRecord(1, 64, 60, 0.5, 1.0, 1.0, 0.5,
                                wall_time_s=0.25))
    # the measurable sum skips fused (None) generations
    assert log.wall_time_s == 0.25
    back = SearchLog.from_json(log.to_json())
    assert back.records[0].wall_time_s is None
    assert back.records[1].wall_time_s == 0.25
    # pre-flight-recorder logs without the field still load (default 0.0)
    old = {"generation": 0, "evaluations": 8, "valid": 8,
           "best_fitness": 1.0, "best_cycles": 1.0,
           "best_energy_pj": 1.0, "best_edp": 1.0}
    assert GenerationRecord.from_dict(old).wall_time_s == 0.0
    # timing=False strips wall_time_s entirely (the reproducibility form)
    assert "wall_time_s" not in log.to_dict(timing=False)["records"][0]


# ----------------------------------------------------------------------
# service + islands integration
# ----------------------------------------------------------------------
def test_service_fused_requests():
    from repro.dse import EvaluationService
    with EvaluationService(autostart=False) as svc:
        client = svc.client("t0")
        carry, ys = client.run_fused(lambda: ("carry", {"n": 1}))
        assert carry == "carry" and ys == {"n": 1}
        assert svc.stats()["fused_chunks"] == 1
        assert svc.stats()["batches"] == 0


def test_islands_fused_mode():
    from repro.dse import run_islands
    design = scnn_like(three_level_arch())
    wl = matmul(64, 48, 32, densities={"A": ("uniform", 0.4),
                                       "B": ("uniform", 0.6)})
    cons = MapspaceConstraints(budget=256, seed=0, spatial={1: {"n": 8}})
    r = run_islands(design, wl, cons, n_islands=2, generations=4,
                    migrate_every=2, key=0, fused=True)
    # 2 islands x 2 chunks, all through the service's fused path
    assert r.service_stats["fused_chunks"] == 4
    assert r.service_stats["batches"] == 0
    assert r.best.best is not None and r.best.best.result.valid
    assert r.evaluations == 2 * 4 * 32
    assert all(rec.wall_time_s is None
               for lg in r.logs for rec in lg.records)
