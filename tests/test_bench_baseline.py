"""benchmarks.run --update-baseline merge semantics (bugfix pin).

The filtered-run merge used to keep stale rows for renamed/removed
benchmarks forever, silently shrinking what the --gate step compares;
``merge_baseline`` now prunes them (with warnings) using per-row bench
module provenance."""
import warnings

import pytest

from benchmarks.run import check_regression, merge_baseline


def _row(name, module=None, derived="cphc=100"):
    row = {"name": name, "us_per_call": 1.0, "derived": derived}
    if module is not None:
        row["module"] = module
    return row


def test_merge_replaces_and_keeps_unrelated_rows():
    baseline = [_row("a1", "mod_a"), _row("b1", "mod_b")]
    fresh = [_row("a1", "mod_a", derived="cphc=200")]
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # clean merge: no warnings
        merged = merge_baseline(baseline, fresh, ran_modules={"mod_a"},
                                known_modules={"mod_a", "mod_b"})
    by_name = {r["name"]: r for r in merged}
    assert by_name["a1"]["derived"] == "cphc=200"    # replaced
    assert "b1" in by_name                           # untouched module


def test_merge_prunes_renamed_row_of_rerun_module():
    """A module that re-ran but no longer emits a row (renamed bench
    row) must not leave the old name in the baseline."""
    baseline = [_row("old_name", "mod_a"), _row("b1", "mod_b")]
    fresh = [_row("new_name", "mod_a")]
    with pytest.warns(UserWarning, match="old_name"):
        merged = merge_baseline(baseline, fresh, ran_modules={"mod_a"},
                                known_modules={"mod_a", "mod_b"})
    names = {r["name"] for r in merged}
    assert names == {"new_name", "b1"}


def test_merge_prunes_rows_of_removed_module():
    """A row whose module left the registry is stale even when that
    module did not run this time."""
    baseline = [_row("gone1", "mod_gone"), _row("b1", "mod_b")]
    fresh = [_row("a1", "mod_a")]
    with pytest.warns(UserWarning, match="mod_gone"):
        merged = merge_baseline(baseline, fresh, ran_modules={"mod_a"},
                                known_modules={"mod_a", "mod_b"})
    assert {r["name"] for r in merged} == {"a1", "b1"}


def test_merge_keeps_legacy_rows_with_warning():
    """Pre-provenance rows survive (we cannot attribute them) but warn
    so the operator regenerates a tagged baseline."""
    baseline = [_row("legacy")]                      # no module field
    fresh = [_row("a1", "mod_a")]
    with pytest.warns(UserWarning, match="provenance"):
        merged = merge_baseline(baseline, fresh, ran_modules={"mod_a"},
                                known_modules={"mod_a"})
    assert {r["name"] for r in merged} == {"legacy", "a1"}


def test_gate_still_fails_on_empty_comparison():
    """With pruning in place the no-shared-metrics guard still trips
    when a rename slips through without a baseline refresh."""
    msgs = check_regression([_row("new")], [_row("old")])
    assert msgs and "compared nothing" in msgs[0]
