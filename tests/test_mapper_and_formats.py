"""Mapper search validity + format model unit tests + engine CPHC, plus
the fused-projection model variant (hillclimb B2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Sparseloop, matmul
from repro.core.density import DenseModel, UniformModel
from repro.core.formats import analyze_tile_format
from repro.core.mapper import MapspaceConstraints, search
from repro.core.presets import (coordinate_list_design, dense_design,
                                two_level_arch)
from repro.core.taxonomy import RankFormat, TensorFormat


def test_mapper_finds_valid_mappings():
    wl = matmul(32, 32, 32, densities={"A": ("uniform", 0.3),
                                       "B": ("uniform", 0.3)})
    design = coordinate_list_design(two_level_arch(buffer_kwords=8))
    res = search(design, wl, MapspaceConstraints(budget=120, seed=3))
    assert res.valid > 0
    assert res.best is not None and res.best.result.valid
    # the found mapping respects the capacity constraint
    for lv in res.best.result.levels:
        if lv.capacity_words != float("inf"):
            assert lv.occupancy_words_max <= lv.capacity_words


def test_mapper_better_than_naive():
    """Search should beat the first-sampled mapping on EDP."""
    wl = matmul(32, 32, 32)
    design = dense_design(two_level_arch())
    res1 = search(design, wl, MapspaceConstraints(budget=1, seed=0))
    res = search(design, wl, MapspaceConstraints(budget=200, seed=0))
    assert res.best.edp <= res1.best.edp


# ----------------------------------------------------------------------
# MapspaceConstraints edge cases (enumeration AND strategy paths)
# ----------------------------------------------------------------------
def _one_level_design():
    from repro.core.arch import (Architecture, ComputeLevel,
                                 StorageLevel)
    arch = Architecture(
        name="flat",
        levels=(StorageLevel("Mem", float("inf"), 64, 10.0, 10.0, 0.1),),
        compute=ComputeLevel("MAC", instances=16, mac_energy_pj=1.0,
                             gated_energy_pj=0.05))
    return dense_design(arch)


@pytest.mark.parametrize("strategy", [None, "es"])
def test_empty_permutation_constraint(strategy):
    """permutations={} must behave exactly like no constraint (every
    level's order is free), not crash or pin anything."""
    wl = matmul(8, 8, 8)
    design = dense_design(two_level_arch())
    cons = MapspaceConstraints(budget=32, seed=0, permutations={})
    kw = {} if strategy is None else {"strategy": strategy, "key": 0}
    res = search(design, wl, cons, **kw)
    assert res.best is not None and res.best.result.valid
    res.best_nest.validate(wl)


@pytest.mark.parametrize("strategy", [None, "hillclimb"])
def test_single_level_design(strategy):
    """num_levels == 1: the only factor split is the full bound at L0 and
    the mapspace is pure permutation."""
    wl = matmul(4, 8, 4)
    design = _one_level_design()
    cons = MapspaceConstraints(budget=16, seed=0)
    kw = {} if strategy is None else {"strategy": strategy, "key": 0}
    res = search(design, wl, cons, **kw)
    assert res.best is not None and res.best.result.valid
    res.best_nest.validate(wl)
    assert res.best_nest.num_levels == 1
    prod = {}
    for lp in res.best_nest.loops:
        prod[lp.rank] = prod.get(lp.rank, 1) * lp.bound
    assert prod == {r: b for r, b in wl.rank_bounds.items() if b > 1}


@pytest.mark.parametrize("strategy", [None, "es"])
def test_unit_bound_ranks(strategy):
    """Ranks with bound 1 (matmul(1, K, N): degenerate m) never emit
    loops but must not break enumeration or genome encoding."""
    wl = matmul(1, 16, 8, densities={"A": ("uniform", 0.5)})
    design = dense_design(two_level_arch())
    cons = MapspaceConstraints(budget=32, seed=0)
    kw = {} if strategy is None else {"strategy": strategy, "key": 0}
    res = search(design, wl, cons, **kw)
    assert res.best is not None and res.best.result.valid
    res.best_nest.validate(wl)
    assert all(lp.rank != "m" for lp in res.best_nest.loops)


# ----------------------------------------------------------------------
# Format models (Sec. 5.3.3 formulas)
# ----------------------------------------------------------------------
def test_bitmask_overhead_density_independent():
    """Overhead_B = #elements x 1 bit, regardless of density (Sec 5.3.3)."""
    fmt = TensorFormat.of(RankFormat.B)
    lo = analyze_tile_format(fmt, (64,), UniformModel(1024, 0.1))
    hi = analyze_tile_format(fmt, (64,), UniformModel(1024, 0.9))
    assert lo.metadata_bits_avg == hi.metadata_bits_avg == 64.0


def test_rle_overhead_tracks_nnz():
    """Overhead_RLE = #nonempty x run_bits (Sec 5.3.3)."""
    fmt = TensorFormat.of(RankFormat.RLE, coord_bits=5)
    lo = analyze_tile_format(fmt, (64,), UniformModel(4096, 0.1))
    hi = analyze_tile_format(fmt, (64,), UniformModel(4096, 0.5))
    assert lo.metadata_bits_avg == pytest.approx(0.1 * 64 * 5, rel=0.05)
    assert hi.metadata_bits_avg == pytest.approx(0.5 * 64 * 5, rel=0.05)


def test_uop_overhead_per_fiber():
    fmt = TensorFormat.of(RankFormat.UOP, RankFormat.CP, coord_bits=8)
    st_ = analyze_tile_format(fmt, (8, 16), UniformModel(4096, 0.25))
    # top rank: 1 fiber x 2 offsets x 8 bits = 16 bits
    assert st_.ranks[0].metadata_bits_avg == 16.0
    # bottom rank: ~nnz x 8 bits
    assert st_.ranks[1].metadata_bits_avg == pytest.approx(
        0.25 * 128 * 8, rel=0.1)


def test_dense_tile_footprint_equals_size():
    fmt = TensorFormat.uncompressed()
    st_ = analyze_tile_format(fmt, (16, 16), DenseModel(256))
    assert st_.footprint_words(16) == 256
    assert st_.compression_rate(16) == 1.0


@given(st.floats(0.05, 0.95), st.integers(4, 128))
@settings(max_examples=30, deadline=None)
def test_compression_rate_bounds(density, tile):
    """CP compression can never store more than tile_size payloads and
    the footprint is monotone in density."""
    fmt = TensorFormat.of(RankFormat.CP, coord_bits=8)
    model = UniformModel(tensor_size=max(1024, tile), density=density)
    st_ = analyze_tile_format(fmt, (tile,), model)
    assert 0 <= st_.data_words_avg <= tile
    assert st_.metadata_bits_avg >= 0


def test_engine_cphc_positive():
    wl = matmul(64, 64, 64, densities={"A": ("uniform", 0.3)})
    from repro.core.mapping import nest
    mapping = nest(2, ("m", 8, 1), ("n", 8, 1),
                   ("n", 8, 0), ("k", 64, 0), ("m", 8, 0))
    cphc = Sparseloop(dense_design(two_level_arch())).cphc(wl, mapping)
    # CPHC grows with workload size (evaluation is O(1)); at 64^3 it is
    # modest — the Table-5 bench measures DNN-layer scale where it is
    # in the tens-to-hundreds
    assert cphc > 0.02


# ----------------------------------------------------------------------
# Fused parallel-block variant (hillclimb B2) stays numerically sane
# ----------------------------------------------------------------------
def test_fused_parallel_block_forward_decode():
    from repro.configs import get_config
    from repro.models import get_api
    cfg = dataclasses.replace(get_config("command-r-35b", reduced=True),
                              fused_proj=True)
    api = get_api(cfg)
    params, specs = api.init(cfg, jax.random.PRNGKey(0))
    assert "w_fused" in jax.tree.leaves(
        {"k": list(params["blocks"].keys())}) or \
        "w_fused" in params["blocks"]
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg.vocab_size)
    h, _ = api.forward_train(params, tok, cfg, remat=False)
    assert not bool(jnp.isnan(h).any())
    logits, cache = api.prefill(params, tok, cfg, 24)
    l2, _ = api.decode_step(params, jnp.zeros((2, 1), jnp.int32), cache,
                            16, cfg)
    assert not bool(jnp.isnan(l2).any())
