"""End-to-end validation of the analytical model against the actual-data
reference simulator — the reproduction of the paper's Sec. 6.3 validation
methodology.  Target band: the paper reports 0.1%-8% average error."""
import numpy as np
import pytest

from repro.core import Sparseloop, evaluate_microarch, matmul, nest
from repro.core import refsim
from repro.core.presets import (bitmask_design, coordinate_list_design,
                                dense_design, dstc_like, scnn_like,
                                stc_like, tc_arch, three_level_arch,
                                two_level_arch)

RNG = np.random.default_rng(42)


def sample(shape, d):
    return (RNG.random(shape) < d).astype(np.float32)


def mc_validate(design, wl, mapping, arrays_fn, trials=30):
    ev = Sparseloop(design).evaluate(wl, mapping, check_capacity=False)
    cyc = en = 0.0
    for _ in range(trials):
        st = refsim.simulate(wl, mapping, design.safs, arrays_fn(),
                             design.level_names)
        r = evaluate_microarch(design.arch, st, check_capacity=False)
        cyc += r.cycles / trials
        en += r.energy_pj / trials
    return ev.result, cyc, en


MAP2 = nest(2,
            ("m", 4, 1), ("n", 2, 1), ("n", 4, 1, "spatial"),
            ("n", 2, 0), ("k", 16, 0), ("m", 4, 0))


@pytest.mark.parametrize("maker,tol_cyc,tol_e", [
    (dense_design, 0.001, 0.001),
    (bitmask_design, 0.01, 0.05),
    (coordinate_list_design, 0.08, 0.08),
])
def test_two_level_designs_within_paper_band(maker, tol_cyc, tol_e):
    wl = matmul(16, 16, 16, densities={"A": ("uniform", 0.25),
                                       "B": ("uniform", 0.5)})
    d = maker(two_level_arch(buffer_kwords=64))
    res, cyc, en = mc_validate(
        d, wl, MAP2,
        lambda: {"A": sample((16, 16), .25), "B": sample((16, 16), .5)})
    assert res.valid
    assert abs(res.cycles - cyc) / cyc <= tol_cyc
    assert abs(res.energy_pj - en) / en <= tol_e


def test_three_level_scnn_like():
    wl = matmul(16, 8, 16, densities={"A": ("uniform", 0.3),
                                      "B": ("uniform", 0.4)})
    n3 = nest(3,
              ("m", 4, 2), ("k", 2, 2),
              ("n", 4, 1), ("m", 2, 1), ("n", 2, 1, "spatial"),
              ("n", 2, 0), ("k", 4, 0), ("m", 2, 0))
    d = scnn_like(three_level_arch())
    res, cyc, en = mc_validate(
        d, wl, n3,
        lambda: {"A": sample((16, 8), .3), "B": sample((8, 16), .4)})
    assert abs(res.cycles - cyc) / cyc <= 0.08
    assert abs(res.energy_pj - en) / en <= 0.08


def test_stc_2to4_exact_2x_speedup():
    """Sec. 6.3.5: with the fixed-structured 2:4 model, Sparseloop produces
    an exact 2x speedup over dense — 100% accuracy."""
    M = K = N = 64
    n2 = nest(2,
              ("m", 16, 1), ("n", 4, 1), ("n", 4, 1, "spatial"),
              ("n", 4, 0), ("m", 4, 0), ("k", 64, 0))
    dense = Sparseloop(dense_design(tc_arch("tc-dense"))).evaluate(
        matmul(M, K, N), n2)
    sp = Sparseloop(stc_like(2, 4)).evaluate(
        matmul(M, K, N, densities={"A": ("structured", {"n": 2, "m": 4})}),
        n2)
    assert dense.result.cycles / sp.result.cycles == pytest.approx(2.0)


def test_dstc_latency_trend_vs_density():
    """Fig. 13 trend: DSTC latency falls as operands get sparser."""
    M = K = N = 64
    n2 = nest(2,
              ("m", 16, 1), ("n", 4, 1), ("n", 4, 1, "spatial"),
              ("n", 4, 0), ("m", 4, 0), ("k", 64, 0))
    lat = []
    for d in (0.9, 0.6, 0.3, 0.1):
        wl = matmul(M, K, N, densities={"A": ("uniform", d),
                                        "B": ("uniform", d)})
        ev = Sparseloop(dstc_like()).evaluate(wl, n2, check_capacity=False)
        lat.append(ev.result.cycles)
    assert all(a > b for a, b in zip(lat, lat[1:]))


def test_bitmask_never_faster_but_cheaper():
    """Fig. 1: bitmask gating saves energy but NOT time."""
    wl = matmul(16, 16, 16, densities={"A": ("uniform", 0.2),
                                       "B": ("uniform", 0.2)})
    d0 = Sparseloop(dense_design(two_level_arch())).evaluate(wl, MAP2)
    d1 = Sparseloop(bitmask_design(two_level_arch())).evaluate(wl, MAP2)
    assert d1.result.cycles == pytest.approx(d0.result.cycles)
    assert d1.result.energy_pj < d0.result.energy_pj


def test_coordlist_faster_at_low_density_slower_metadata_at_high():
    """Fig. 1 crossover: coordinate list wins at low density; at high
    density its multi-bit metadata overhead erodes the advantage."""
    def edp(density):
        wl = matmul(16, 16, 16, densities={"A": ("uniform", density),
                                           "B": ("uniform", density)})
        b = Sparseloop(bitmask_design(two_level_arch())).evaluate(wl, MAP2)
        c = Sparseloop(coordinate_list_design(
            two_level_arch())).evaluate(wl, MAP2)
        return b.result, c.result

    b_lo, c_lo = edp(0.1)
    assert c_lo.cycles < b_lo.cycles          # skipping saves time
    b_hi, c_hi = edp(0.9)
    # dense-ish tensors: coordinate list's metadata overhead dominates
    assert c_hi.energy_pj > b_hi.energy_pj
