"""DSE-as-a-service (repro.dse): cross-request batching parity,
deterministic coalescing, multi-tenant accounting, island search
integration, and shutdown semantics.

The contracts under test:

* routing a population through the service returns EXACTLY what the
  direct ``BucketedModel.evaluate`` path returns (the service is a
  transport, never a model);
* concurrent requests over the same facade coalesce into one
  compiled-program invocation and slice back out per request;
* fixed ``batch_slots`` keep every invocation on one jit shape, so a
  multi-client run compiles once per bucket total;
* island-ES over one shared service matches the scalar oracle on every
  returned winner;
* per-client metrics attribute requests/candidates/latency to the
  tenant that paid for them;
* ``close(drain=False)`` fails queued futures with ``ServiceClosed``
  and later submits are refused, while clean shutdown drains.
"""
import threading

import numpy as np
import pytest
import jax.random as jrandom

from repro.core import Sparseloop, compile_stats, matmul
from repro.core.batched import get_bucketed_model
from repro.core.mapper import MapspaceConstraints
from repro.core.presets import coordinate_list_design, two_level_arch
from repro.dse import EvaluationService, ServiceClosed, run_islands
from repro.obs import metrics
from repro.search import MapspaceEncoding, SearchConfig, run_search

WL = matmul(32, 32, 32, densities={"A": ("uniform", 0.3),
                                   "B": ("uniform", 0.3)})
DESIGN = coordinate_list_design(two_level_arch(buffer_kwords=8))
CONS = MapspaceConstraints(budget=96, seed=0, spatial={1: {"n": 4}})
#: tiny test populations must still take the batched/bucketed route
#: (the scalar fallback would bypass the service entirely)
BATCHED = SearchConfig(batch_threshold=1)


def _decoded_population(n, key=0):
    """(bucketed facade, bounds, rank_ids) for a random population —
    the exact decode the search runner hands the service."""
    enc = MapspaceEncoding(WL, 2, CONS)
    pop = enc.random_population(jrandom.PRNGKey(key), n)
    bucket, bounds, ids = enc.decode_bucketed(pop)
    model = Sparseloop(DESIGN).bucketed_model(WL, bucket)
    return model, bounds, ids


# ----------------------------------------------------------------------
# transport parity + coalescing
# ----------------------------------------------------------------------
def test_service_matches_direct_path_exactly():
    model, bounds, ids = _decoded_population(12)
    direct = model.evaluate(bounds, ids, mesh=None)
    with EvaluationService() as svc:
        served = svc.client("t").evaluate(model, bounds, rank_ids=ids)
    assert set(served) == set(direct)
    for k in direct:
        np.testing.assert_array_equal(served[k], direct[k])


def test_concurrent_requests_coalesce_into_one_batch():
    model, bounds, ids = _decoded_population(16)
    direct = model.evaluate(bounds, ids, mesh=None)
    svc = EvaluationService(autostart=False)
    futs = [svc.submit(model, bounds[s], rank_ids=ids[s], client=c)
            for c, s in (("a", slice(0, 10)), ("b", slice(10, 16)))]
    assert svc.drain_once() == 2
    st = svc.stats()
    assert (st["requests"], st["batches"]) == (2, 1)
    assert st["coalesced_requests"] == 2
    res_a, res_b = futs[0].result(1), futs[1].result(1)
    for k in direct:
        np.testing.assert_array_equal(res_a[k], direct[k][:10])
        np.testing.assert_array_equal(res_b[k], direct[k][10:])
    svc.close()


def test_batch_slots_pin_one_jit_shape():
    # differently-sized requests (5, 11, then 16) through a slotted
    # service must reuse ONE compiled shape: pad short, split long
    model, bounds, ids = _decoded_population(16)
    direct = model.evaluate(bounds, ids, mesh=None)    # warm the program
    with compile_stats.track() as st:
        with EvaluationService(batch_slots=8, autostart=False) as svc:
            c = svc.client("shapes")
            r5 = c.evaluate(model, bounds[:5], rank_ids=ids[:5])
            r11 = c.evaluate(model, bounds[5:], rank_ids=ids[5:])
            r16 = c.evaluate(model, bounds, rank_ids=ids)
    assert st.compiles == 1                 # the (8, slots) shape, once
    for k in direct:
        np.testing.assert_array_equal(
            np.concatenate([r5[k], r11[k]]), direct[k])
        np.testing.assert_array_equal(r16[k], direct[k])


def test_max_batch_splits_preserve_request_boundaries():
    model, bounds, ids = _decoded_population(16)
    direct = model.evaluate(bounds, ids, mesh=None)
    svc = EvaluationService(max_batch=6, autostart=False)
    futs = [svc.submit(model, bounds[i:i + 4], rank_ids=ids[i:i + 4],
                       client=f"c{i}") for i in range(0, 16, 4)]
    svc.drain_once()
    assert svc.stats()["batches"] == 4      # 4-candidate requests never
    for i, fut in enumerate(futs):          # straddle the 6-cap
        for k in direct:
            np.testing.assert_array_equal(
                fut.result(1)[k], direct[k][i * 4:(i + 1) * 4])
    svc.close()


def test_evaluation_errors_fan_out_to_every_future():
    class Boom:
        kind = "boom"

        def evaluate(self, *a, **k):
            raise ValueError("broken model")

    model = Boom()
    svc = EvaluationService(autostart=False)
    futs = [svc.submit(model, np.ones((3, 2)), client=c)
            for c in ("a", "b")]
    svc.drain_once()
    for fut in futs:
        with pytest.raises(ValueError, match="broken model"):
            fut.result(1)
    svc.close()


# ----------------------------------------------------------------------
# multi-tenant accounting
# ----------------------------------------------------------------------
def test_per_client_metrics_attribute_tenants():
    metrics.reset()
    model, bounds, ids = _decoded_population(10)
    with EvaluationService() as svc:
        svc.client("alice").evaluate(model, bounds[:7], rank_ids=ids[:7])
        svc.client("bob").evaluate(model, bounds[7:], rank_ids=ids[7:])
        alice = svc.client_metrics("alice")
        bob = svc.client("bob").metrics()
    assert alice["dse.client.alice.requests"]["value"] == 1
    assert alice["dse.client.alice.candidates"]["value"] == 7
    assert bob["dse.client.bob.candidates"]["value"] == 3
    assert alice["dse.client.alice.request_latency_s"]["count"] == 1
    assert not any("bob" in k for k in alice)
    snap = metrics.snapshot()
    assert snap["dse.candidates"]["value"] == 10
    assert snap["dse.request_latency_s"]["count"] == 2


# ----------------------------------------------------------------------
# search integration
# ----------------------------------------------------------------------
def test_run_search_through_service_matches_direct():
    direct = run_search(DESIGN, WL, CONS, strategy="es", key=3,
                        pop_size=8, generations=3, mesh=None,
                        config=BATCHED)
    with EvaluationService() as svc:
        served = run_search(DESIGN, WL, CONS, strategy="es", key=3,
                            pop_size=8, generations=3, config=BATCHED,
                            service=svc.client("search"))
        assert svc.stats()["requests"] >= 3     # actually routed here
    assert served.best is not None
    assert served.best.edp == pytest.approx(direct.best.edp, rel=1e-9)
    assert served.evaluated == direct.evaluated


def test_islands_share_programs_and_validate_winners():
    metrics.reset()
    with compile_stats.track() as st:
        res = run_islands(DESIGN, WL, CONS, n_islands=3, strategy="es",
                          key=0, pop_size=8, generations=4,
                          migrate_every=2, config=BATCHED)
    # one free-permutation bucket -> one compile for ALL islands
    assert st.compiles <= 1
    assert len(res.per_island) == 3 and len(res.logs) == 3
    assert res.evaluations == 3 * 8 * 4
    assert res.service_stats["clients"] == ["island0", "island1",
                                            "island2"]
    oracle = Sparseloop(DESIGN)
    for r in res.per_island:
        assert r.best is not None
        ev = oracle.evaluate(WL, r.best_nest)
        assert ev.result.valid
        assert ev.edp == pytest.approx(r.best.edp, rel=1e-6)
    assert res.best.best.edp == min(r.best.edp for r in res.per_island)
    # every island shows up as a tenant in the metrics registry
    snap = metrics.snapshot()
    for i in range(3):
        assert snap[f"dse.client.island{i}.requests"]["value"] >= 4


def test_island_migration_disabled_still_runs():
    res = run_islands(DESIGN, WL, CONS, n_islands=2, strategy="es",
                      key=1, pop_size=8, generations=2, migrate_every=0,
                      config=BATCHED)
    assert res.best.best is not None
    assert all(len(log.records) == 2 for log in res.logs)


# ----------------------------------------------------------------------
# shutdown semantics
# ----------------------------------------------------------------------
def test_close_without_drain_fails_pending_and_refuses_submits():
    model, bounds, ids = _decoded_population(6)
    svc = EvaluationService(autostart=False)
    fut = svc.submit(model, bounds, rank_ids=ids, client="late")
    svc.close(drain=False)
    with pytest.raises(ServiceClosed):
        fut.result(1)
    with pytest.raises(ServiceClosed):
        svc.submit(model, bounds, rank_ids=ids)


def test_close_with_drain_serves_pending():
    model, bounds, ids = _decoded_population(6)
    direct = model.evaluate(bounds, ids, mesh=None)
    svc = EvaluationService(autostart=False)
    fut = svc.submit(model, bounds, rank_ids=ids)
    svc.close(drain=True)
    res = fut.result(1)
    np.testing.assert_array_equal(res["edp"], direct["edp"])


def test_context_exit_drains_in_flight_requests():
    model, bounds, ids = _decoded_population(6)
    with EvaluationService() as svc:
        fut = svc.submit(model, bounds, rank_ids=ids)
    assert fut.done()
    assert len(fut.result(0)["edp"]) == 6


# ----------------------------------------------------------------------
# cache thread-safety (the service's precondition)
# ----------------------------------------------------------------------
def test_concurrent_facade_construction_is_safe_and_shared():
    enc = MapspaceEncoding(WL, 2, CONS)
    out, errs = [None] * 8, []

    def build(i):
        try:
            out[i] = get_bucketed_model(DESIGN, WL, enc.bucket)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=build, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert all(m is out[0] for m in out)    # content-cached: ONE facade
