"""Vectorized mapper preset (vmapper -> core.batched): exact parity with
the scalar engine on dense AND sparse designs, and batched throughput."""
import numpy as np
import pytest

from repro.core import Sparseloop, matmul, nest
from repro.core.presets import (bitmask_design, coordinate_list_design,
                                dense_design, two_level_arch)
from repro.core.vmapper import (VDesign, candidate_factors,
                                evaluate_batch)

M = N = K = 16
DA, DB = 0.25, 0.5
ARCH = two_level_arch(buffer_kwords=64)


def engine_eval(design, m1, m0, n1, ns, n0):
    wl = matmul(M, K, N, densities={"A": ("uniform", DA),
                                    "B": ("uniform", DB)})
    loops = []
    if m1 > 1:
        loops.append(("m", int(m1), 1))
    if n1 > 1:
        loops.append(("n", int(n1), 1))
    if ns > 1:
        loops.append(("n", int(ns), 1, "spatial"))
    if n0 > 1:
        loops.append(("n", int(n0), 0))
    loops.append(("k", K, 0))
    if m0 > 1:
        loops.append(("m", int(m0), 0))
    return Sparseloop(design).evaluate(wl, nest(2, *loops),
                                       check_capacity=False).result


def test_dense_exact_parity():
    cand = candidate_factors(M, N, K)
    vm = evaluate_batch(cand, M, N, K, DA, DB, ARCH, VDesign())
    for i in range(len(cand)):
        r = engine_eval(dense_design(ARCH), *cand[i])
        assert float(vm["cycles"][i]) == pytest.approx(r.cycles, rel=1e-6)
        assert float(vm["energy_pj"][i]) == pytest.approx(r.energy_pj,
                                                          rel=1e-6)


@pytest.mark.parametrize("maker,vd", [
    (coordinate_list_design,
     VDesign(compress=True, meta_bits_per_nnz=32, skip=True, gate=True)),
    (bitmask_design,
     VDesign(compress=True, meta_bits_per_coord=2.0, gate=True)),
])
def test_sparse_exact_parity(maker, vd):
    """Since the batched engine runs the full three-step model, sparse
    designs are now *exact* (the old hand-vectorized path only preserved
    ranking); the engine's true best therefore ranks first."""
    cand = candidate_factors(M, N, K)
    vm = evaluate_batch(cand, M, N, K, DA, DB, ARCH, vd)
    design = maker(ARCH)
    true_edp = np.array([engine_eval(design, *cand[i]).edp
                         for i in range(len(cand))])
    np.testing.assert_allclose(np.asarray(vm["edp"]), true_edp, rtol=1e-6)
    order = np.argsort(np.asarray(vm["edp"]))
    assert true_edp[order[0]] == true_edp.min()


def test_vmapper_throughput_exceeds_engine():
    """The headline: batched evaluation is >10x faster per mapping than
    the sequential engine (usually far more)."""
    import time
    cand = candidate_factors(M, N, K)
    evaluate_batch(cand, M, N, K, DA, DB, ARCH, VDesign())  # compile once
    t0 = time.perf_counter()
    for _ in range(5):
        evaluate_batch(cand, M, N, K, DA, DB, ARCH, VDesign())
    per_mapping_vm = (time.perf_counter() - t0) / (5 * len(cand))

    t0 = time.perf_counter()
    n_seq = 20
    for i in range(n_seq):
        engine_eval(dense_design(ARCH), *cand[i % len(cand)])
    per_mapping_engine = (time.perf_counter() - t0) / n_seq
    assert per_mapping_engine / per_mapping_vm > 10
