"""repro.search subsystem: genome encoding validity, strategy
reproducibility (same PRNG key => identical SearchLog), trajectory
monotonicity, mapper integration, oracle-validated winners, and
single-device vs sharded parity."""
import os
import subprocess
import sys
from types import SimpleNamespace

import jax.random as jrandom
import numpy as np
import pytest

from repro.core import matmul
from repro.core.mapper import (MapspaceConstraints, SearchResult,
                               _validated_result, search)
from repro.core.presets import coordinate_list_design, two_level_arch
from repro.search import (STRATEGIES, MapspaceEncoding, SearchLog,
                          crossover, make_strategy, mutate, prime_factors,
                          run_search)

WL = matmul(32, 32, 32, densities={"A": ("uniform", 0.3),
                                   "B": ("uniform", 0.3)})
DESIGN = coordinate_list_design(two_level_arch(buffer_kwords=8))
CONS = MapspaceConstraints(budget=96, seed=0, spatial={1: {"n": 4}})


def test_prime_factors():
    assert prime_factors(1) == []
    assert prime_factors(2) == [2]
    assert prime_factors(12) == [3, 2, 2]
    assert prime_factors(49) == [7, 7]
    assert np.prod(prime_factors(3136)) == 3136


# ----------------------------------------------------------------------
# encoding: every genome decodes to a mapping the engine accepts
# ----------------------------------------------------------------------
@pytest.mark.parametrize("cons", [
    CONS,
    MapspaceConstraints(budget=96, seed=0),                 # no spatial
    MapspaceConstraints(budget=96, seed=0, spatial={1: {"n": 4}},
                        permutations={0: ("n", "k", "m"),
                                      1: ("m", "n")}),      # pinned order
])
def test_random_genomes_decode_to_valid_nests(cons):
    enc = MapspaceEncoding(WL, 2, cons)
    pop = enc.random_population(jrandom.PRNGKey(0), 32)
    assert pop.shape == (32, enc.genome_size)
    for g in pop:
        enc.nest_of(g).validate(WL)     # raises on any invalid mapping


def test_repair_folds_any_genome_into_range():
    enc = MapspaceEncoding(WL, 2, CONS)
    rng = np.random.default_rng(0)
    wild = rng.integers(-1000, 1000, size=(16, enc.genome_size))
    fixed = enc.repair(wild)
    assert (fixed >= 0).all() and (fixed < enc.cardinality).all()
    for g in fixed:
        enc.nest_of(g).validate(WL)


def test_decode_population_partitions_and_groups_by_structure():
    enc = MapspaceEncoding(WL, 2, CONS)
    pop = enc.random_population(jrandom.PRNGKey(1), 48)
    groups = enc.decode_population(pop)
    seen = np.concatenate([idx for _, idx, _ in groups])
    assert sorted(seen.tolist()) == list(range(48))
    for template, idx, bounds in groups:
        assert bounds.shape == (len(idx), template.num_slots)
        for g, b in zip(pop[idx], bounds):
            nest = enc.nest_of(g)
            assert nest.structure() == tuple(
                s for s, bb in zip(template.slots, b) if int(bb) > 1)


def test_crossover_swaps_whole_factor_blocks():
    enc = MapspaceEncoding(WL, 2, CONS)
    pa = np.zeros((8, enc.genome_size), np.int64)
    pb = enc.repair(np.ones((8, enc.genome_size), np.int64))
    child = crossover(jrandom.PRNGKey(2), pa, pb, enc)
    for row in child:
        for blk in range(enc.num_blocks):
            sel = enc.gene_block == blk
            assert (row[sel] == pa[0][sel]).all() or \
                   (row[sel] == pb[0][sel]).all()


def test_mutation_always_changes_a_gene():
    enc = MapspaceEncoding(WL, 2, CONS)
    pop = enc.random_population(jrandom.PRNGKey(3), 16)
    out = mutate(jrandom.PRNGKey(4), pop, enc, rate=0.0)
    assert out.shape == pop.shape
    # rate=0 still resamples exactly one forced gene per genome; with
    # cardinality > 1 some draws will differ across 16 genomes
    assert (out != pop).any()
    assert ((out >= 0) & (out < enc.cardinality)).all()


# ----------------------------------------------------------------------
# reproducibility + trajectories
# ----------------------------------------------------------------------
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_same_key_same_searchlog(strategy):
    r1 = run_search(DESIGN, WL, CONS, strategy=strategy, key=11)
    r2 = run_search(DESIGN, WL, CONS, strategy=strategy, key=11)
    # byte-reproducibility is stated on the timing-stripped form: the
    # wall-clock fields measure the machine, not the search
    assert r1.log.to_json(timing=False) == r2.log.to_json(timing=False)
    assert r1.best_nest == r2.best_nest
    assert (r1.evaluated, r1.valid) == (r2.evaluated, r2.valid)
    # ... and the timing fields are actually populated
    assert all(r.wall_time_s > 0 for r in r1.log.records)
    assert r1.log.timing["wall_s"] > 0


def test_trajectory_monotone_and_serializable():
    res = run_search(DESIGN, WL, CONS, strategy="es", key=0)
    traj = res.log.trajectory("best_edp")
    assert len(traj) == len(res.log.records) >= 1
    assert all(a >= b for a, b in zip(traj, traj[1:]))
    roundtrip = SearchLog.from_json(res.log.to_json())
    assert roundtrip.to_json() == res.log.to_json()
    assert res.log.evaluations == res.evaluated


def test_search_finds_valid_oracle_checked_mapping():
    res = run_search(DESIGN, WL, CONS, strategy="hillclimb", key=0)
    assert res.best is not None and res.best.result.valid
    res.best_nest.validate(WL)
    # the scalar oracle agrees with the fitness the search tracked
    assert res.best.edp == pytest.approx(res.log.best_fitness, rel=1e-6)


# ----------------------------------------------------------------------
# mapper integration
# ----------------------------------------------------------------------
def test_mapper_search_strategy_dispatch():
    res = search(DESIGN, WL, CONS, strategy="es", key=5)
    assert isinstance(res, SearchResult)
    assert res.log is not None and res.log.strategy == "es"
    assert 0 < res.evaluated <= CONS.budget
    # default path unchanged: no log, same signature
    enum = search(DESIGN, WL, CONS)
    assert enum.log is None


def test_mapper_search_string_objective_enumeration_path():
    """objective='cycles' (no strategy) must optimize cycles, not crash."""
    res = search(DESIGN, WL, MapspaceConstraints(budget=24, seed=0),
                 objective="cycles")
    assert res.best is not None and res.best.result.valid
    with pytest.raises(ValueError, match="objective"):
        search(DESIGN, WL, CONS, objective="watts")


def test_budget_caps_strategy_evaluations():
    """cons.budget is a hard cap even when it is below pop_size."""
    res = run_search(DESIGN, WL,
                     MapspaceConstraints(budget=8, seed=0),
                     strategy="es", key=0)   # default pop_size 32 > 8
    assert 0 < res.evaluated <= 8


def test_use_batched_false_forces_scalar_dispatch_with_strategy():
    cons = MapspaceConstraints(budget=64, seed=0,
                               permutations={0: ("n", "k", "m"),
                                             1: ("m", "n")})
    r_scalar = search(DESIGN, WL, cons, strategy="es", key=9,
                      use_batched=False, pop_size=64)
    r_auto = search(DESIGN, WL, cons, strategy="es", key=9, pop_size=64)
    # same key => same candidates; scalar vs batched agree to round-off
    assert r_scalar.best_nest == r_auto.best_nest
    assert r_scalar.best.edp == pytest.approx(r_auto.best.edp, rel=1e-6)


def test_mapper_search_strategy_rejects_callable_objective():
    with pytest.raises(ValueError, match="metric name"):
        search(DESIGN, WL, CONS, objective=lambda ev: ev.cycles,
               strategy="es")
    with pytest.raises(TypeError):
        search(DESIGN, WL, CONS, key=3)      # strategy kwargs w/o strategy
    with pytest.raises(ValueError, match="unknown strategy"):
        search(DESIGN, WL, CONS, strategy="gradient-descent")


def test_strategy_search_actual_density_rides_batched_engine():
    """Actual-data density models — formerly the scalar-only fallback —
    now lower to a tile-occupancy histogram and ride the bucketed
    engine: zero scalar-path population evaluations."""
    from repro.core import compile_stats
    rng = np.random.default_rng(0)
    wl = matmul(8, 8, 8, densities={
        "A": ("actual", (rng.random((8, 8)) < 0.4).astype(float))})
    with compile_stats.track() as st:
        res = run_search(DESIGN, wl,
                         MapspaceConstraints(budget=32, seed=0),
                         strategy="es", key=0, pop_size=16,
                         batch_threshold=1)
    assert res.best is not None and res.best.result.valid
    res.best_nest.validate(wl)
    assert st.scalar_evals == 0, st.as_dict()
    assert st.batched_evals >= 32


# ----------------------------------------------------------------------
# oracle validation of batched winners
# ----------------------------------------------------------------------
def test_validated_result_skips_oracle_rejected_candidates():
    """If the batched ranking leaks a mapping the scalar oracle rejects,
    the walk drops it and returns the next-best validated one."""
    rejected = []

    class StubModel:
        def evaluate(self, workload, nest, check_capacity=True):
            ok = nest != "bad"
            if not ok:
                rejected.append(nest)
            return SimpleNamespace(result=SimpleNamespace(valid=ok),
                                   edp=1.0, cycles=1.0, energy_pj=1.0)

    nests = ["bad", "good", "better-but-invalid-flag"]
    edp = np.asarray([1.0, 2.0, 3.0])
    valid = np.asarray([True, True, False])
    res = _validated_result(StubModel(), WL, lambda i: nests[i],
                            edp=edp, valid=valid, n_eval=7)
    assert res.best_nest == "good"
    assert res.evaluated == 7
    assert res.valid == 1            # "bad" dropped from the valid count
    assert rejected == ["bad"]


def test_validated_result_all_rejected_returns_empty():
    class StubModel:
        def evaluate(self, workload, nest, check_capacity=True):
            return SimpleNamespace(result=SimpleNamespace(valid=False))

    res = _validated_result(StubModel(), WL, lambda i: i,
                            edp=np.asarray([1.0, 2.0]),
                            valid=np.asarray([True, True]), n_eval=2)
    assert res.best is None and res.best_nest is None and res.valid == 0


# ----------------------------------------------------------------------
# sharding: 1 device == N simulated shards
# ----------------------------------------------------------------------
def test_sharded_search_matches_single_device():
    """Run the same fixed-key search in a subprocess with 2 simulated
    host devices (population sharded via shard_map) and compare the
    trajectory against the in-process single-device run."""
    cons = MapspaceConstraints(budget=64, seed=0, spatial={1: {"n": 4}},
                               permutations={0: ("n", "k", "m"),
                                             1: ("m", "n")})
    single = run_search(DESIGN, WL, cons, strategy="es", key=42,
                        pop_size=64, mesh=None)
    code = (
        "import jax, json\n"
        "assert len(jax.devices()) == 2, jax.devices()\n"
        "import numpy as np\n"
        "from repro.core import matmul\n"
        "from repro.core.mapper import MapspaceConstraints\n"
        "from repro.core.presets import coordinate_list_design, "
        "two_level_arch\n"
        "from repro.search import run_search\n"
        "wl = matmul(32, 32, 32, densities={'A': ('uniform', 0.3), "
        "'B': ('uniform', 0.3)})\n"
        "design = coordinate_list_design(two_level_arch(buffer_kwords=8))\n"
        "cons = MapspaceConstraints(budget=64, seed=0, "
        "spatial={1: {'n': 4}}, permutations={0: ('n', 'k', 'm'), "
        "1: ('m', 'n')})\n"
        "res = run_search(design, wl, cons, strategy='es', key=42, "
        "pop_size=64, mesh='auto')\n"
        "print('LOG=' + res.log.to_json())\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", ""))
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = [ln for ln in proc.stdout.splitlines()
               if ln.startswith("LOG=")][-1]
    sharded = SearchLog.from_json(payload[len("LOG="):])
    t1 = single.log.trajectory("best_edp")
    t2 = sharded.trajectory("best_edp")
    assert len(t1) == len(t2) > 0
    np.testing.assert_allclose(t1, t2, rtol=1e-6)
