"""Traced parametric density interface (workload-as-data, ISSUE 4).

Pins the contract that lets one compiled program serve a whole network:
every density model lowers to a fixed-shape parameter vector + kind id
(plus, for actual-data, a tile-occupancy histogram), and the static
``*_t`` traced forms behind the ``TracedDensityStats`` model-id switch
reproduce the scalar oracle methods to <= 1e-6 — across kinds, at
non-divisible tile sizes, and on all-zero tiles."""
import numpy as np
import pytest

from repro.core.density import (ActualDataModel, BandedModel, DenseModel,
                                DensityCaps, StructuredModel,
                                TracedDensityStats, UniformModel,
                                caps_for_models)


def _stats_for(models):
    return TracedDensityStats(caps_for_models(models))


def _check_parity(model, stats, tile_sizes, rel=1e-6):
    """Traced switch-dispatched stats == scalar oracle methods."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    with enable_x64():
        params = jnp.asarray(model.params())
        hist = np.zeros((3, stats.caps.hist))
        table = model.hist_table()
        hist[:, : table.shape[1]] = table
        hist = jnp.asarray(hist)
        kind = model.kind_id
        for t in tile_sizes:
            pe = float(stats.prob_empty(kind, params, hist, float(t)))
            ed = float(stats.expected_density(kind, params, hist,
                                              float(t)))
            mx = float(stats.max_nnz(kind, params, hist, float(t)))
            assert pe == pytest.approx(model.prob_empty(t), abs=rel), \
                (type(model).__name__, t)
            assert ed == pytest.approx(model.expected_density(t),
                                       rel=rel, abs=rel), \
                (type(model).__name__, t)
            assert mx == pytest.approx(model.max_nnz(t), rel=rel), \
                (type(model).__name__, t)


# ----------------------------------------------------------------------
# actual-data: the tile-occupancy histogram lowering
# ----------------------------------------------------------------------
def test_actual_histogram_matches_scalar_oracle():
    """Every tile size of a ragged (non-power-of-two) array, including
    non-divisible ones, reproduces the scalar ActualDataModel exactly."""
    rng = np.random.default_rng(0)
    a = (rng.random((7, 13)) < 0.3).astype(float)      # 91 elements
    m = ActualDataModel(data=a)
    stats = _stats_for([m])
    # every tile size + past-the-end clamping (t > tensor_size)
    _check_parity(m, stats, list(range(1, 92)) + [100, 1000])


def test_actual_histogram_all_zero_and_dense_rows():
    """All-zero arrays (every tile empty) and a single dense row (the
    Fig. 9 coordinate-dependence case) both survive the lowering."""
    zero = ActualDataModel(data=np.zeros((6, 6)))
    assert zero.density == 0.0
    _check_parity(zero, _stats_for([zero]), [1, 2, 5, 7, 36, 50])

    a = np.zeros((8, 8))
    a[0, :] = 1.0
    row = ActualDataModel(data=a)
    stats = _stats_for([row])
    _check_parity(row, stats, [1, 3, 8, 9, 64])
    # spot-check the documented scalar facts through the traced path
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    with enable_x64():
        hist = np.zeros((3, stats.caps.hist))
        hist[:, :64] = row.hist_table()
        pe = float(stats.prob_empty(row.kind_id,
                                    jnp.asarray(row.params()),
                                    jnp.asarray(hist), 8.0))
        assert pe == pytest.approx(7 / 8)


def test_actual_histogram_table_semantics():
    """Row meanings: [prob_empty, expected_density, max_nnz] per aligned
    1-D tile size, non-divisible tails dropped like the scalar path."""
    a = np.asarray([1.0, 0.0, 0.0, 1.0, 1.0])   # n=5
    m = ActualDataModel(data=a)
    table = m.hist_table()
    assert table.shape == (3, 5)
    # t=2 -> tiles [1,0], [0,1] (tail element dropped): none empty
    assert table[0, 1] == 0.0
    assert table[1, 1] == pytest.approx(0.5)
    assert table[2, 1] == 1.0
    # t=3 -> single tile [1,0,0]: nonempty, density 1/3, max 1
    assert table[0, 2] == 0.0
    assert table[1, 2] == pytest.approx(1 / 3)
    assert table[2, 2] == 1.0


def test_actual_batched_wrappers_traceable_under_vmap():
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    rng = np.random.default_rng(3)
    m = ActualDataModel(data=(rng.random(48) < 0.4).astype(float))
    assert m.batched
    with enable_x64():
        tiles = jnp.asarray([1.0, 3.0, 7.0, 16.0, 48.0])
        pe = jax.jit(jax.vmap(m.prob_empty_b))(tiles)
        mx = jax.jit(jax.vmap(m.max_nnz_b))(tiles)
        for t, a_, b_ in zip(tiles, pe, mx):
            assert float(a_) == pytest.approx(m.prob_empty(int(t)))
            assert float(b_) == float(m.max_nnz(int(t)))


# ----------------------------------------------------------------------
# statistical kinds through the same switch
# ----------------------------------------------------------------------
def test_traced_stats_parity_all_statistical_kinds():
    models = [
        DenseModel(tensor_size=64),
        UniformModel(tensor_size=256, density=0.3),   # nnz rounding != d
        StructuredModel(tensor_size=128, n=2, m=4),
        BandedModel(rows=16, cols=24, half_band=2),
    ]
    stats = _stats_for(models)
    for m in models:
        _check_parity(m, stats, [1, 2, 3, 4, 6, 8, 16, 25, 64],
                      rel=1e-9)


def test_caps_cover_and_pow2_rounding():
    banded = BandedModel(rows=48, cols=48, half_band=3)
    actual = ActualDataModel(data=np.ones(100))
    caps = caps_for_models([banded, actual])
    assert caps.coord >= 48 and caps.hist >= 100 and caps.div >= 48
    # powers of two, so similarly-sized layers share a program
    for v in (caps.coord, caps.div, caps.hist):
        assert v & (v - 1) == 0
    assert caps.covers(caps_for_models([banded]))
    assert not DensityCaps().covers(caps)
    merged = DensityCaps(coord=4).merge(DensityCaps(hist=8))
    assert merged == DensityCaps(coord=4, div=0, hist=8)
    # uniform-only workloads need no padding at all -> shared everywhere
    assert caps_for_models([UniformModel(1024, 0.5)]) == DensityCaps()
