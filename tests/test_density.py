"""Density model unit + property tests (paper Sec. 5.3.2, Table 4, Fig. 9)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.density import (ActualDataModel, BandedModel, DenseModel,
                                StructuredModel, UniformModel,
                                make_density_model)


def test_uniform_matches_monte_carlo():
    S, d, T = 1024, 0.25, 16
    m = UniformModel(tensor_size=S, density=d)
    rng = np.random.default_rng(0)
    trials = 3000
    empties = 0
    for _ in range(trials):
        idx = rng.choice(S, size=m.nnz, replace=False)
        a = np.zeros(S)
        a[idx] = 1
        if a[:T].sum() == 0:
            empties += 1
    assert abs(m.prob_empty(T) - empties / trials) < 0.03
    assert abs(m.expected_density(T) - d) < 1e-12


def test_uniform_fig9_shape_dependence():
    """Fig. 9: smaller tiles have higher empty probability."""
    m = UniformModel(tensor_size=4096, density=0.5)
    probs = [m.prob_empty(t) for t in (1, 2, 4, 8, 16)]
    assert all(a > b for a, b in zip(probs, probs[1:]))
    assert abs(m.prob_empty(1) - 0.5) < 1e-9


def test_structured_deterministic_at_block():
    m = StructuredModel(tensor_size=1024, n=2, m=4)
    assert m.expected_density(128) == 0.5
    assert m.prob_empty(4) == 0.0       # every block holds exactly 2 nnz
    assert m.prob_empty(8) == 0.0
    assert m.max_nnz(8) == 4            # exactly n per block
    assert m.max_nnz(6) == 4            # 1 full block + partial capped at n
    # sub-block tiles can be empty: 1 element empty w.p. 1 - 2/4
    assert abs(m.prob_empty(1) - 0.5) < 1e-9


def test_banded_coordinate_dependence():
    m = BandedModel(rows=64, cols=64, half_band=2)
    p_empty, dens = m.tile_stats(8, 8)
    # most tiles are off-diagonal and empty (8x8 grid: diagonal + adjacent
    # sub-diagonal tiles are nonempty -> 22/64 nonempty)
    assert p_empty > 0.6
    assert 0 < dens < 0.2
    assert 0 < m.density < 0.2


def test_actual_data_exact():
    a = np.zeros((8, 8))
    a[0, :] = 1.0          # one dense row
    m = ActualDataModel(data=a)
    assert m.density == pytest.approx(1 / 8)
    # aligned 8-element (row) tiles: exactly 1 of 8 nonempty
    assert m.prob_empty(8) == pytest.approx(7 / 8)
    assert m.max_nnz(8) == 8


@given(st.integers(16, 512), st.floats(0.01, 0.99), st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_uniform_properties(S, d, T):
    T = min(T, S)
    m = UniformModel(tensor_size=S, density=d)
    p = m.prob_empty(T)
    assert 0.0 <= p <= 1.0
    # P(empty) <= (1 - density of one element)
    assert p <= m.prob_empty(1) + 1e-9
    # expectations within bounds
    assert 0.0 <= m.expected_nnz(T) <= T + 1e-9
    assert m.max_nnz(T) >= math.floor(m.expected_nnz(T)) - 1


@given(st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_structured_prob_empty_monotone(m_block):
    m = StructuredModel(tensor_size=64 * m_block, n=1, m=m_block)
    probs = [m.prob_empty(t) for t in range(1, m_block + 1)]
    assert all(a >= b - 1e-12 for a, b in zip(probs, probs[1:]))
    assert probs[-1] == 0.0 or m_block == 1


def test_banded_batched_closed_forms_match_scalar():
    """The traceable banded expressions reproduce the scalar grid-count
    loops exactly (prob_empty / expected_density / max_nnz)."""
    from jax.experimental import enable_x64
    with enable_x64():
        for (rows, cols, w) in [(64, 64, 2), (64, 48, 5), (37, 53, 3),
                                (16, 16, 0), (8, 64, 7)]:
            m = BandedModel(rows=rows, cols=cols, half_band=w)
            for t in (1, 2, 3, 4, 6, 8, 16, 25, 30, 64, 100, 255, 256,
                      512, rows * cols):
                assert float(m.prob_empty_b(float(t))) == pytest.approx(
                    m.prob_empty(t), abs=1e-12), (rows, cols, w, t)
                assert float(m.expected_density_b(float(t))) == \
                    pytest.approx(m.expected_density(t),
                                  rel=1e-9), (rows, cols, w, t)
                assert float(m.max_nnz_b(float(t))) == m.max_nnz(t), \
                    (rows, cols, w, t)


def test_banded_batched_traceable_under_vmap():
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    m = BandedModel(rows=32, cols=32, half_band=2)
    assert m.batched
    with enable_x64():
        tiles = jnp.asarray([1.0, 4.0, 16.0, 64.0, 256.0])
        pe = jax.jit(jax.vmap(m.prob_empty_b))(tiles)
        ed = jax.jit(jax.vmap(m.expected_density_b))(tiles)
        for t, a, b in zip(tiles, pe, ed):
            assert float(a) == pytest.approx(m.prob_empty(int(t)),
                                             abs=1e-12)
            assert float(b) == pytest.approx(m.expected_density(int(t)),
                                             rel=1e-9)


def test_make_density_model_dispatch():
    assert isinstance(make_density_model(None, 10), DenseModel)
    assert isinstance(make_density_model(("uniform", 0.5), 10), UniformModel)
    assert isinstance(
        make_density_model(("structured", {"n": 2, "m": 4}), 16),
        StructuredModel)
    assert isinstance(
        make_density_model(("banded", {"rows": 8, "cols": 8,
                                       "half_band": 1}), 64), BandedModel)
    assert isinstance(
        make_density_model(("actual", np.ones((4, 4))), 16), ActualDataModel)
