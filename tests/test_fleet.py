"""Fleet extraction / sweep / validation contracts.

The load-bearing claims, each pinned exactly:

* **parameter exactness** — the extraction walk reproduces
  ``ModelConfig.param_count()`` to the parameter for every CONFIG and
  REDUCED config (all 10 families: GQA, MLA+MoE, SSM/xLSTM, Mamba2
  hybrid, encoder-decoder);
* **FLOP exactness** — ``total_flops`` matches independent closed-form
  per-family formulas for prefill AND decode;
* **merge/dedup** — identical layers collapse at extraction
  (count=num_layers) and identical shapes collapse at evaluation,
  with the avoided work counted in ``compile_stats.dedup_evals``;
* **production sharding** — per-device shapes under the 16x16 mesh
  match hand-computed Megatron-style splits, and indivisible axes
  replicate instead of going fractional;
* **compile accounting** — a REDUCED sweep stays within its structural
  compile bound with zero scalar-path evaluations, and the batched
  results match the scalar reference oracle;
* **validation arms** — the deterministic (no wall-clock) arms of the
  kernel-agreement harness pass: N:M packed-bytes traffic sign and
  kernel correctness.
"""
import dataclasses

import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.core import compile_stats
from repro.core.advisor import LayerAdvice, advise, tpu_mapping
from repro.core.engine import Sparseloop
from repro.core.workload import matmul
from repro.fleet.extract import (MeshSpec, extract_network,
                                 production_mesh_spec, shard_entries)
from repro.fleet.sweep import (WIN_MARGIN, compile_bound, dedupe_shapes,
                               default_options, fleet_sweep)
from repro.fleet.validate import (DETERMINISTIC_ARMS, kernel_cell,
                                  validate_fleet)
from repro.launch.mesh import production_mesh_shape

ALL_CONFIGS = [(name, reduced) for name in ARCH_NAMES
               for reduced in (False, True)]


# ----------------------------------------------------------------------
# parameter exactness (every family, every config)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("name,reduced", ALL_CONFIGS,
                         ids=[f"{n}{'-reduced' if r else ''}"
                              for n, r in ALL_CONFIGS])
def test_param_exactness(name, reduced):
    cfg = get_config(name, reduced=reduced)
    net = extract_network(cfg, "prefill", seq_len=32, batch=2)
    assert net.total_params == cfg.param_count(), (
        f"{cfg.name}: extracted {net.total_params} params, "
        f"param_count() says {cfg.param_count()}")


def test_decode_touches_all_decoder_weights():
    # decode runs the same weight matmuls (encoder-side weights excluded
    # for enc_dec models, which only run the encoder at prefill)
    cfg = get_config("qwen3-4b")
    pre = extract_network(cfg, "prefill", seq_len=32, batch=2)
    dec = extract_network(cfg, "decode", batch=4)
    assert dec.total_params == pre.total_params == cfg.param_count()


# ----------------------------------------------------------------------
# FLOP exactness (closed forms per family)
# ----------------------------------------------------------------------

def test_flops_gqa_prefill_and_decode():
    cfg = get_config("qwen3-4b")
    L, d, H, kv, hd = (cfg.num_layers, cfg.d_model, cfg.num_heads,
                       cfg.num_kv_heads, cfg.head_dim)
    dff, v = cfg.d_ff, cfg.vocab_size
    S, B = 128, 2
    T = S * B
    weights = 2 * T * (L * (d * (H + 2 * kv) * hd     # qkv
                            + H * hd * d              # o_proj
                            + d * 2 * dff + dff * d)  # gated FFN
                       + d * v)                       # lm head
    attn = L * H * B * (2 * S * hd * S + 2 * S * S * hd)
    net = extract_network(cfg, "prefill", seq_len=S, batch=B)
    assert net.total_flops == weights + attn

    C = 512
    dec = extract_network(cfg, "decode", batch=B, ctx_len=C)
    dweights = 2 * B * (L * (d * (H + 2 * kv) * hd + H * hd * d
                             + d * 2 * dff + dff * d) + d * v)
    dattn = L * H * B * (2 * 1 * hd * C + 2 * 1 * C * hd)
    assert dec.total_flops == dweights + dattn


def test_flops_mla_moe():
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    m, e = cfg.mla, cfg.moe
    d, v, L, H = cfg.d_model, cfg.vocab_size, cfg.num_layers, cfg.num_heads
    S, B = 64, 2
    T = S * B                       # T*top_k % num_experts == 0: exact
    assert (T * e.top_k) % e.num_experts == 0
    tok = (T * e.top_k) // e.num_experts
    qk, vd = m.qk_nope_head_dim + m.qk_rope_head_dim, m.v_head_dim
    expect = 0
    for layer in range(L):
        expect += 2 * T * (d * H * qk                       # q_proj
                           + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                           + m.kv_lora_rank * H * (m.qk_nope_head_dim
                                                   + vd)
                           + H * vd * d)                    # o_proj
        expect += H * B * (2 * S * qk * S + 2 * S * S * vd)
        if cfg.is_moe_layer(layer):
            expect += 2 * T * d * e.num_experts             # router
            expect += e.num_experts * 2 * tok * (d * 2 * e.expert_d_ff
                                                 + e.expert_d_ff * d)
            expect += e.num_shared_experts * 2 * T * (
                d * 2 * e.shared_d_ff + e.shared_d_ff * d)
        else:
            expect += 2 * T * (d * 2 * cfg.d_ff + cfg.d_ff * d)
    expect += 2 * T * d * v
    net = extract_network(cfg, "prefill", seq_len=S, batch=B)
    assert net.total_flops == expect


def test_flops_xlstm():
    cfg = get_config("xlstm-350m")
    d, di = cfg.d_model, cfg.ssm_expand * cfg.d_model
    S, B = 32, 4
    T = S * B
    # each block: up (d -> 2di) + down (di -> d); no FFN, no attention
    expect = cfg.num_layers * (2 * T * d * 2 * di + 2 * T * di * d) \
        + 2 * T * d * cfg.vocab_size
    net = extract_network(cfg, "prefill", seq_len=S, batch=B)
    assert net.total_flops == expect
    assert net.attention_matmuls() == ()


def test_flops_hybrid_shared_attn():
    cfg = get_config("zamba2-7b", reduced=True)
    d, di = cfg.d_model, cfg.ssm_expand * cfg.d_model
    L, H, hd = cfg.num_layers, cfg.num_heads, cfg.head_dim
    sd = cfg.hybrid.shared_attn_d_ff
    apps = L // cfg.hybrid.period
    S, B = 16, 2
    T = S * B
    per_mamba = (2 * T * d * 2 * di + 2 * T * di * d
                 + 2 * T * di * (2 * cfg.ssm_state + 3)
                 + 2 * T * (d * 2 * cfg.d_ff + cfg.d_ff * d))
    shared = apps * (2 * T * (d * (cfg.q_dim + 2 * cfg.kv_dim)
                              + cfg.q_dim * d + d * 2 * sd + sd * d)
                     + H * B * (2 * S * hd * S + 2 * S * S * hd))
    expect = L * per_mamba + shared + 2 * T * d * cfg.vocab_size
    net = extract_network(cfg, "prefill", seq_len=S, batch=B)
    assert net.total_flops == expect
    # the shared block's weights materialize ONCE (not per application)
    qkv = next(e for e in net.matmuls if e.name == "shared_attn_qkv")
    assert qkv.count == apps and qkv.param_instances == 1


def test_flops_enc_dec():
    cfg = get_config("whisper-base", reduced=True)
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    L, EL, H, hd = (cfg.num_layers, cfg.enc_layers, cfg.num_heads,
                    cfg.head_dim)
    S, B, E = 64, 2, 8              # S > dec_max_len=32: clamps
    DS = min(S, cfg.dec_max_len)
    T, Te = DS * B, E * B
    dec_self = L * (2 * T * (d * (cfg.q_dim + 2 * cfg.kv_dim)
                             + cfg.q_dim * d + d * 2 * dff + dff * d)
                    + H * B * (2 * DS * hd * DS + 2 * DS * DS * hd))
    enc = EL * (2 * Te * (d * 3 * d + d * d + d * 2 * dff + dff * d)
                + H * B * (2 * E * hd * E + 2 * E * E * hd))
    cross = L * (2 * Te * (d * d + d * d)         # cached K/V
                 + 2 * T * (d * d + d * d)        # per-step Q/O
                 + H * B * (2 * DS * hd * E + 2 * DS * E * hd))
    expect = dec_self + enc + cross + 2 * T * d * v
    net = extract_network(cfg, "prefill", seq_len=S, batch=B, enc_len=E)
    assert net.total_flops == expect
    # decode drops the encoder + cached cross-K/V, keeps per-step Q/O
    dec = extract_network(cfg, "decode", batch=B, enc_len=E)
    names = {e.name for e in dec.matmuls}
    assert "enc_qkv" not in names and "cross_k_proj" not in names
    assert "cross_q_proj" in names and "cross_attn_qk" in names


# ----------------------------------------------------------------------
# merge + dedup
# ----------------------------------------------------------------------

def test_identical_layers_merge():
    cfg = get_config("qwen3-4b")
    net = extract_network(cfg, "prefill", seq_len=32, batch=2)
    qkv = [e for e in net.matmuls if e.name == "attn_qkv"]
    assert len(qkv) == 1
    assert qkv[0].count == cfg.num_layers
    assert qkv[0].param_instances == cfg.num_layers
    assert qkv[0].weight_params == (qkv[0].K * qkv[0].N
                                    * cfg.num_layers)


def test_dedupe_shapes_fanout():
    from repro.fleet.extract import LayerMatmul
    entries = [LayerMatmul("a", 8, 16, 32), LayerMatmul("b", 8, 16, 64),
               LayerMatmul("c", 8, 16, 32), LayerMatmul("d", 8, 16, 32)]
    unique, index = dedupe_shapes(entries)
    assert len(unique) == 2
    assert [unique[i] for i in index] == [e.shape for e in entries]


def test_dedup_evals_counter():
    with compile_stats.track() as st:
        compile_stats.record_dedup_evals(7)
    assert st.dedup_evals == 7
    delta = st - compile_stats.CompileStats(dedup_evals=3)
    assert delta.dedup_evals == 4
    assert st.copy().dedup_evals == 7


# ----------------------------------------------------------------------
# production sharding
# ----------------------------------------------------------------------

def test_production_mesh_spec_matches_launch():
    spec = production_mesh_spec()
    assert spec.axes == production_mesh_shape()
    assert spec.size == 256
    assert spec.axis_names == ("data", "model")
    assert production_mesh_spec(multi_pod=True).size == 512


def test_production_shard_command_r():
    cfg = get_config("command-r-35b")
    mesh = production_mesh_spec()
    net = shard_entries(extract_network(cfg, "prefill"), mesh)
    by = {e.name: e for e in net.matmuls}
    # T = 4096*16 over data=16 -> M 4096; qkv N = (64+16)*128 = 10240
    # over model=16 -> 640; o_proj K = 8192 over model -> 512
    assert by["attn_qkv"].shape == (4096, 8192, 640)
    assert by["attn_qkv"].count == cfg.num_layers == 40
    assert by["attn_o_proj"].shape == (4096, 512, 8192)
    # attention score count = 64 heads * 16 seqs * 40 layers = 40960,
    # heads sharded on model (16) then sequences on data (16) -> 160
    assert by["attn_qk"].count == 160
    dec = shard_entries(extract_network(cfg, "decode"), mesh)
    assert {e.name: e for e in dec.matmuls}["attn_qkv"].M == 256 // 16


def test_indivisible_axes_replicate():
    cfg = get_config("qwen3-4b")
    mesh = MeshSpec((("data", 3), ("model", 7)))
    net = shard_entries(
        extract_network(cfg, "prefill", seq_len=9, batch=2), mesh)
    by = {e.name: e for e in net.matmuls}
    assert by["attn_qkv"].M == 6              # 18 tokens / data=3
    # N = (32+16)*128 = 6144, not divisible by 7 -> replicated
    assert by["attn_qkv"].N == 6144
    assert by["ffn_down"].K == cfg.d_ff       # 9728 % 7 != 0


# ----------------------------------------------------------------------
# sweep: compile accounting + scalar parity + verdicts
# ----------------------------------------------------------------------

def test_reduced_sweep_compile_accounting():
    # the same config listed twice guarantees cross-network duplicate
    # shapes, so dedup must fire
    names = ("qwen3-4b", "qwen3-4b")
    with compile_stats.track() as st:
        rep = fleet_sweep(names, reduced=True, seq_len=32, batch=2)
    assert st.compiles <= rep.compile_bound
    assert rep.compile_bound == len(rep.option_names)
    assert st.scalar_evals == 0
    assert st.dedup_evals > 0
    assert rep.total_entries == len(rep.rows)
    assert rep.unique_shapes <= rep.total_entries
    for r in rep.rows:
        assert r.verdict in ("compress", "dense")
        assert r.options["dense"]["cycles"] == r.dense_cycles
        if r.verdict == "compress":
            assert r.best_cycles * WIN_MARGIN < r.dense_cycles
        assert r.speedup >= 1.0


def test_sweep_matches_scalar_oracle():
    # one weight shape through the fleet path vs the scalar reference
    opt = default_options(((2, 4),))
    rep = fleet_sweep(("qwen3-4b",), reduced=True, phases=("decode",),
                      nm_options=((2, 4),), mesh=None, batch=16)
    dense_engine = Sparseloop(opt[0].design)
    nm_engine = Sparseloop(opt[1].design)
    for r in rep.rows:
        if r.layer != "lm_head":
            continue
        wl = matmul(r.M, r.K, r.N)
        ev = dense_engine.evaluate(wl, tpu_mapping(r.M, r.K, r.N),
                                   check_capacity=False)
        assert r.dense_cycles == pytest.approx(ev.cycles, rel=1e-6)
        wl_nm = matmul(r.M, r.K, r.N, densities=opt[1].densities)
        ev_nm = nm_engine.evaluate(wl_nm, tpu_mapping(r.M, r.K, r.N),
                                   check_capacity=False)
        assert r.options["nm-2:4"]["cycles"] == pytest.approx(
            ev_nm.cycles, rel=1e-6)
        break
    else:
        pytest.fail("lm_head row missing")


def test_compile_bound_is_layer_count_independent():
    opts = default_options()
    few = extract_network(get_config("qwen3-4b", reduced=True),
                          "prefill", seq_len=16, batch=1).matmuls
    many = [e for name in ARCH_NAMES[:4] for e in extract_network(
        get_config(name, reduced=True), "prefill", seq_len=16,
        batch=1).matmuls]
    assert (compile_bound(opts, few) == compile_bound(opts, many)
            == len(opts))


def test_crossover_values_on_grid():
    grid = (8, 64, 512)
    rep = fleet_sweep(("qwen3-4b",), reduced=True, phases=("decode",),
                      batch=16, crossover=True, crossover_grid=grid)
    assert rep.crossover
    for kn, per_opt in rep.crossover.items():
        K, N = map(int, kn.split("x"))
        assert K > 0 and N > 0
        for opt, last_win in per_opt.items():
            assert opt in rep.option_names
            assert last_win is None or last_win in grid


# ----------------------------------------------------------------------
# advisor back-compat + validation (deterministic arms only)
# ----------------------------------------------------------------------

def test_advise_backcompat():
    cfg = get_config("qwen3-4b")
    with compile_stats.track() as st:
        adv = advise(cfg, tokens_per_device=8, tp=16)
    assert adv and all(isinstance(a, LayerAdvice) for a in adv)
    # N:M keeps n/m of the weights plus coordinate overhead, so an
    # HBM-bound matmul's speedup is bounded by the inverse byte ratio:
    # 2:4 -> 1/0.5625, 2:8 -> 1/(0.25 * (1 + 3/32))
    bound = {"dense": 1.0, "nm-2:4": 1.0 / 0.5625,
             "nm-2:8": 1.0 / (0.25 * (1 + 3 / 32))}
    for a in adv:
        assert a.dense_bottleneck in ("compute", "HBM")
        assert a.best_name in bound
        assert 1.0 <= a.speedup <= bound[a.best_name] + 0.01
    assert st.scalar_evals == 0
    names = {a.layer for a in adv}
    assert {"attn_qkv", "ffn_gate_up", "lm_head"} <= names


def test_kernel_cell_padding():
    assert kernel_cell(8, 544, 300) == (8, 576, 512)
    assert kernel_cell(1000, 512, 512) == (128, 512, 512)
    assert kernel_cell(3, 100, 100, bs=64, min_dim=128) == (8, 128, 128)


def test_validate_deterministic_arms():
    rows = validate_fleet(("qwen3-4b", "xlstm-350m"),
                          arms=DETERMINISTIC_ARMS, reps=1,
                          min_dim=128, max_cells_per_config=1)
    assert rows
    assert {r.arm for r in rows} == set(DETERMINISTIC_ARMS)
    bad = [r for r in rows if not r.agree]
    assert not bad, [dataclasses.asdict(r) for r in bad]
    for r in rows:
        if r.arm == "nm-correct":
            assert r.measured < 1e-3
        if r.arm == "nm-traffic":
            # 2:4 f32 packs to ~0.53x the dense bytes
            assert r.measured > 1.5
