"""Pallas kernel validation: shape/dtype sweeps + property tests against
the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.block_mm.ops import (block_indices, block_mm_ref,
                                        gated_mm, skip_mm)
from repro.kernels.nm_spmm.ops import nm_spmm, nm_spmm_ref
from repro.sparsity import nm_prune_dense, pack_nm, unpack_nm_with

RNG = np.random.default_rng(7)


# ----------------------------------------------------------------------
# nm_spmm
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,m", [(2, 4), (1, 4), (2, 6), (2, 8), (4, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nm_spmm_matches_ref(n, m, dtype):
    M, K, N = 32, 12 * m, 64
    a = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    w = nm_prune_dense(jnp.asarray(RNG.normal(size=(K, N)), jnp.float32),
                       n, m)
    wv, wi = pack_nm(w, n, m)
    out = nm_spmm(a, wv.astype(dtype), wi, n=n, m=m, bm=32, bk=3 * m,
                  bn=32)
    ref = nm_spmm_ref(a, wv.astype(dtype), wi, n, m)
    tol = 0.25 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("bm,bk,bn", [(16, 8, 32), (32, 16, 16),
                                      (64, 32, 64)])
def test_nm_spmm_block_shape_sweep(bm, bk, bn):
    n, m = 2, 4
    M, K, N = 64, 64, 64
    a = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = nm_prune_dense(jnp.asarray(RNG.normal(size=(K, N)), jnp.float32),
                       n, m)
    wv, wi = pack_nm(w, n, m)
    out = nm_spmm(a, wv, wi, n=n, m=m, bm=bm, bk=bk, bn=bn)
    ref = nm_spmm_ref(a, wv, wi, n, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


@given(st.integers(0, 10_000), st.sampled_from([(2, 4), (2, 8), (1, 4)]))
@settings(max_examples=12, deadline=None)
def test_nm_pack_roundtrip(seed, nm):
    """Property: pack -> unpack is the identity on N:M-pruned weights."""
    n, m = nm
    rng = np.random.default_rng(seed)
    w = nm_prune_dense(jnp.asarray(rng.normal(size=(8 * m, 16)),
                                   jnp.float32), n, m)
    wv, wi = pack_nm(w, n, m)
    w2 = unpack_nm_with(wv, wi, n, m)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w))
    # compression: exactly n/m of the dense values are stored
    assert wv.size == w.size * n // m


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_nm_prune_structure(seed):
    """Property: every m-block of the pruned weight has <= n nonzeros."""
    rng = np.random.default_rng(seed)
    n, m = 2, 4
    w = nm_prune_dense(jnp.asarray(rng.normal(size=(32, 8)), jnp.float32),
                       n, m)
    blocks = np.asarray(w).reshape(-1, m, 8)
    assert ((blocks != 0).sum(axis=1) <= n).all()


# ----------------------------------------------------------------------
# block_mm gate/skip
# ----------------------------------------------------------------------
@pytest.mark.parametrize("density", [0.1, 0.5, 0.9, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gated_and_skip_match_ref(density, dtype):
    M, K, N = 32, 128, 128
    bk = bn = 32
    a = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    w = jnp.asarray(RNG.normal(size=(K, N)), dtype)
    mask = (RNG.random((K // bk, N // bn)) < density).astype(np.int32)
    mask[0, 0] = 1
    jm = jnp.asarray(mask)
    wm = w * jnp.repeat(jnp.repeat(jm.astype(w.dtype), bk, 0), bn, 1)
    ref = block_mm_ref(a, w, jm, bk, bn)
    tol = 0.3 if dtype == jnp.bfloat16 else 1e-4
    g = gated_mm(a, w, jm, bm=32, bk=bk, bn=bn)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref), atol=tol,
                               rtol=tol)
    ki, ji = block_indices(mask)
    s = skip_mm(a, wm, jnp.asarray(ki), jnp.asarray(ji), bm=32, bk=bk,
                bn=bn)
    np.testing.assert_allclose(np.asarray(s), np.asarray(ref), atol=tol,
                               rtol=tol)


def test_skip_grid_is_shorter():
    """The skip kernel's grid scales with nnz blocks (time savings), the
    gated kernel's with all blocks (energy-only savings) — the paper's
    central gate-vs-skip distinction, observable in the launch count."""
    mask = np.zeros((8, 4), np.int32)
    mask[0, :] = 1          # one nonzero block per column
    ki, ji = block_indices(mask)
    assert len(ki) == 4     # skip: 4 of 32 blocks visited


@pytest.mark.parametrize("n,m", [(2, 4), (2, 8), (1, 4)])
def test_nm_spmm_packed_offsets(n, m):
    """Bit-packed CP offsets reach the full-compression layout bound
    (EXPERIMENTS.md §Perf kernel iteration) and stay exact."""
    from repro.sparsity.nm import (offsets_bits, pack_offsets,
                                   unpack_offsets)
    M, K, N = 32, 16 * m, 64
    a = jnp.asarray(RNG.normal(size=(M, K)), jnp.float32)
    w = nm_prune_dense(jnp.asarray(RNG.normal(size=(K, N)), jnp.float32),
                       n, m)
    wv, wi = pack_nm(w, n, m)
    wip = pack_offsets(wi, m)
    np.testing.assert_array_equal(
        np.asarray(unpack_offsets(wip, m, wi.shape[0])),
        np.asarray(wi, np.int32))
    out = nm_spmm(a, wv, wip, n=n, m=m, bm=32, bk=4 * m, bn=32,
                  packed=True)
    ref = nm_spmm_ref(a, wv, wi, n, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # metadata bytes shrink by the packing factor
    assert wip.size * (8 // offsets_bits(m)) == wi.size
