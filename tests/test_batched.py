"""Batched engine (core.batched) parity with the scalar reference oracle.

The contract: ``BatchedModel`` / ``Sparseloop.evaluate_batch`` reproduce
scalar ``Sparseloop.evaluate`` cycles/energy to <= 1e-6 relative across
design families (dense, gating, skipping+compressed), and the batched
``mapper.search`` dispatch finds the identical best-EDP mapping."""
import numpy as np
import pytest

from repro.core import Sparseloop, matmul
from repro.core.batched import NestTemplate
from repro.core.mapper import MapspaceConstraints, search
from repro.core.presets import (bitmask_design, coordinate_list_design,
                                dense_design, two_level_arch)
from repro.core.vmapper import SPMSPM_TEMPLATE, candidate_factors

M = N = K = 16
DA, DB = 0.25, 0.5
ARCH = two_level_arch(buffer_kwords=64)
WL = matmul(M, K, N, densities={"A": ("uniform", DA),
                                "B": ("uniform", DB)})


def _bounds():
    """(C, 6) spMspM template bounds for every (m1,m0,n1,ns,n0) tiling."""
    f = candidate_factors(M, N, K)
    m1, m0, n1, ns, n0 = (f[:, i] for i in range(5))
    k = np.full_like(m1, K)
    return np.stack([m1, n1, ns, n0, k, m0], axis=1)


@pytest.mark.parametrize("maker", [dense_design, bitmask_design,
                                   coordinate_list_design])
def test_parity_with_scalar_oracle(maker):
    """>= 50 sampled nests per preset, cycles AND energy <= 1e-6 rel."""
    design = maker(ARCH)
    model = Sparseloop(design)
    bounds = _bounds()
    assert len(bounds) >= 50
    out = model.batched_model(WL, SPMSPM_TEMPLATE,
                              check_capacity=False).evaluate(bounds)
    for i, b in enumerate(bounds):
        nest = SPMSPM_TEMPLATE.nest_with(b)
        ev = model.evaluate(WL, nest, check_capacity=False)
        assert out["cycles"][i] == pytest.approx(ev.cycles, rel=1e-6)
        assert out["energy_pj"][i] == pytest.approx(ev.energy_pj, rel=1e-6)
        assert out["edp"][i] == pytest.approx(ev.edp, rel=1e-6)
        assert out["compute_actual"][i] == pytest.approx(
            ev.result.compute_actual, rel=1e-6)


def test_capacity_validity_matches_scalar():
    """The batched capacity check flags exactly the mappings the scalar
    engine rejects (worst-case footprint incl. metadata)."""
    design = coordinate_list_design(two_level_arch(buffer_kwords=0.25))
    model = Sparseloop(design)
    bounds = _bounds()
    out = model.batched_model(WL, SPMSPM_TEMPLATE,
                              check_capacity=True).evaluate(bounds)
    ref = [model.evaluate(WL, SPMSPM_TEMPLATE.nest_with(b)).result.valid
           for b in bounds]
    assert out["valid"].tolist() == ref
    assert 0 < sum(ref) < len(ref)  # the check actually separates


def test_evaluate_batch_groups_mixed_templates():
    """The public API accepts nests of mixed structure and returns arrays
    aligned with the input order."""
    design = dense_design(ARCH)
    model = Sparseloop(design)
    bounds = _bounds()[:8]
    nests = [SPMSPM_TEMPLATE.nest_with(b) for b in bounds]
    out = model.evaluate_batch(WL, nests, check_capacity=False)
    assert out["cycles"].shape == (len(nests),)
    for i, nest in enumerate(nests):
        ev = model.evaluate(WL, nest, check_capacity=False)
        assert out["cycles"][i] == pytest.approx(ev.cycles, rel=1e-6)


def test_parity_banded_density():
    """Banded workloads now run on the batched engine (closed-form
    coordinate-dependent statistics) — parity with the scalar oracle."""
    wl = matmul(M, K, N, densities={
        "A": ("banded", {"rows": M, "cols": K, "half_band": 2}),
        "B": ("uniform", DB)})
    design = coordinate_list_design(ARCH)
    model = Sparseloop(design)
    bounds = _bounds()[::3]
    out = model.batched_model(wl, SPMSPM_TEMPLATE,
                              check_capacity=False).evaluate(bounds)
    for i, b in enumerate(bounds):
        ev = model.evaluate(wl, SPMSPM_TEMPLATE.nest_with(b),
                            check_capacity=False)
        assert out["cycles"][i] == pytest.approx(ev.cycles, rel=1e-6)
        assert out["energy_pj"][i] == pytest.approx(ev.energy_pj,
                                                    rel=1e-6)


def test_parity_actual_data_density():
    """actual-data workloads — formerly the only scalar-only density
    model — now ride the batched engine through the tile-occupancy
    histogram lowering; parity with the scalar oracle."""
    rng = np.random.default_rng(7)
    wl = matmul(M, K, N, densities={
        "A": ("actual", (rng.random((M, K)) < 0.35).astype(float)),
        "B": ("uniform", DB)})
    design = coordinate_list_design(ARCH)
    model = Sparseloop(design)
    bounds = _bounds()[::5]
    out = model.batched_model(wl, SPMSPM_TEMPLATE,
                              check_capacity=False).evaluate(bounds)
    for i, b in enumerate(bounds):
        ev = model.evaluate(wl, SPMSPM_TEMPLATE.nest_with(b),
                            check_capacity=False)
        assert out["cycles"][i] == pytest.approx(ev.cycles, rel=1e-6)
        assert out["energy_pj"][i] == pytest.approx(ev.energy_pj,
                                                    rel=1e-6)


def test_unknown_density_spec_unsupported():
    """batched_supported still guards against unknown density specs."""
    from repro.core.batched import batched_supported
    wl = matmul(M, K, N, densities={"A": ("no-such-model", 0.5)})
    assert not batched_supported(dense_design(ARCH), wl)


def test_template_roundtrip():
    b = np.asarray([4, 1, 2, 2, K, 4])
    nest = SPMSPM_TEMPLATE.nest_with(b)
    assert all(lp.bound > 1 for lp in nest.loops)
    t = NestTemplate.of_nest(nest)
    assert t.num_levels == 2
    np.testing.assert_array_equal(
        t.bounds_of(nest), [lp.bound for lp in nest.loops])


# ----------------------------------------------------------------------
def test_mapper_search_regression_batched_vs_scalar():
    """Pin: batched dispatch finds the identical best-EDP mapping (and
    bookkeeping) as the pre-existing scalar loop."""
    wl = matmul(32, 32, 32, densities={"A": ("uniform", 0.3),
                                       "B": ("uniform", 0.3)})
    design = coordinate_list_design(two_level_arch(buffer_kwords=8))
    cons = MapspaceConstraints(budget=100, seed=3,
                               permutations={0: ("n", "k", "m"),
                                             1: ("m", "n")})
    scalar = search(design, wl, cons, use_batched=False)
    batched = search(design, wl, cons, use_batched=True)
    assert scalar.best_nest == batched.best_nest
    assert batched.best.edp == pytest.approx(scalar.best.edp, rel=1e-9)
    assert (scalar.evaluated, scalar.valid) == (batched.evaluated,
                                                batched.valid)
