"""Dataflow (Step One) traffic vs the brute-force loop-walking simulator,
plus structural/property invariants of the reuse model."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Workload, matmul, mv, nest
from repro.core.dataflow import analyze_dataflow, leader_tile_bounds
from repro.core.mapping import Loop, LoopNest
from repro.core import refsim
from repro.core.taxonomy import SAFSpec


def _dense_cmp(wl, mapping):
    dense = analyze_dataflow(wl, mapping)
    arrays = {t.name: np.ones(t.dim_sizes(wl.rank_bounds))
              for t in wl.tensors}
    sim = refsim.simulate(wl, mapping, SAFSpec(), arrays,
                          [f"L{s}" for s in range(mapping.num_levels)])
    for t in wl.tensors:
        is_out = t.name == wl.output
        for s in range(mapping.num_levels):
            a, b = dense.of(t.name, s), sim.of(t.name, s)
            if is_out:
                model_rd = (a.writeback_words + a.rmw_read_words
                            + a.read_words)
                assert model_rd == pytest.approx(b.reads.dense), \
                    (t.name, s, "reads")
                assert a.update_words == pytest.approx(b.updates.dense), \
                    (t.name, s, "updates")
            else:
                assert a.read_words == pytest.approx(b.reads.dense), \
                    (t.name, s, "reads")
                if s < mapping.num_levels - 1:
                    assert a.fill_words == pytest.approx(b.fills.dense), \
                        (t.name, s, "fills")


def test_matmul_output_stationary():
    wl = matmul(8, 8, 8)
    _dense_cmp(wl, nest(2, ("m", 8, 1), ("n", 8, 0), ("k", 8, 0)))


def test_matmul_weight_stationary_spatial():
    wl = matmul(8, 16, 8)
    _dense_cmp(wl, nest(2,
                        ("k", 2, 1), ("m", 4, 1), ("n", 2, 1, "spatial"),
                        ("n", 4, 0), ("k", 8, 0), ("m", 2, 0)))


def test_matmul_reduction_outer_partial_evictions():
    # k at the outermost level forces partial-sum eviction/refetch
    wl = matmul(4, 8, 4)
    _dense_cmp(wl, nest(2, ("k", 4, 1), ("m", 4, 1),
                        ("n", 4, 0), ("k", 2, 0)))


def test_mv_three_level():
    wl = mv(16, 16)
    _dense_cmp(wl, nest(3,
                        ("m", 2, 2), ("k", 2, 2),
                        ("m", 4, 1), ("k", 2, 1),
                        ("k", 4, 0), ("m", 2, 0)))


def test_fig10_leader_tiles():
    """The paper's Fig. 10: the same SAF has different leader tiles under
    different mappings."""
    wl = matmul(4, 4, 8)
    A, B = wl.tensor("A"), wl.tensor("B")
    # Mapping 1: innermost k0 -> leader is a single A value
    m1 = nest(2, ("m", 4, 1), ("n", 2, 1), ("n", 4, 1, "spatial"),
              ("n", 2, 0), ("k", 4, 0))
    lb1 = leader_tile_bounds(m1, 0, B, A)
    assert A.tile_size(lb1) == 1
    # Mapping 2: innermost m0 (irrelevant to B) -> leader is a column of A
    m2 = nest(2, ("n", 2, 1), ("n", 4, 1, "spatial"),
              ("n", 2, 0), ("k", 4, 0), ("m", 4, 0))
    lb2 = leader_tile_bounds(m2, 0, B, A)
    assert A.tile_size(lb2) == 4
    assert lb2.get("m") == 4


@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
       st.integers(0, 5))
@settings(max_examples=25, deadline=None)
def test_traffic_invariants(lm, lk, ln, seed):
    """Property: compute count is exact; child fills never exceed parent
    reads; all counts non-negative."""
    M, K, N = 2 ** lm, 2 ** lk, 2 ** ln
    wl = matmul(M, K, N)
    rng = np.random.default_rng(seed)

    def split(x):
        a = int(rng.choice([f for f in range(1, x + 1) if x % f == 0]))
        return a, x // a

    m1, m0 = split(M)
    k1, k0 = split(K)
    n1, n0 = split(N)
    loops = [lp for lp in (Loop("m", m1, 1), Loop("k", k1, 1),
                           Loop("n", n1, 1), Loop("n", n0, 0),
                           Loop("k", k0, 0), Loop("m", m0, 0))
             if lp.bound >= 1]
    mapping = LoopNest(loops=tuple(loops), num_levels=2)
    dense = analyze_dataflow(wl, mapping)
    assert dense.dense_computes == M * K * N
    for t in wl.tensors:
        for s in range(2):
            tl = dense.of(t.name, s)
            assert tl.read_words >= 0 and tl.fill_words >= 0
            assert tl.update_words >= 0 and tl.rmw_read_words >= 0
    for t in wl.input_tensors:
        # data served downward >= data resident below (conservation-ish)
        assert dense.of(t.name, 1).read_words >= \
            dense.of(t.name, 0).tile_size
